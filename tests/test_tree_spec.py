"""Tree-structured speculation (DESIGN.md §11): topology invariants,
lossless-vs-greedy across strategies/backends/KV layouts, masked tree-arm
bit-parity with dedicated static runs, and the tree-mask kernel vs its
XLA oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tree as T
from repro.core.ngram_tables import NGramTables, build_bigram, build_unigram
from repro.core.spec_engine import (SpecConfig, generate, greedy_reference,
                                    init_decode_state, spec_step)
from repro.models import model as M
from repro.models.config import ModelConfig

F32 = dict(param_dtype=jnp.float32, compute_dtype=jnp.float32)


def _tables(params, cfg, k_max=8, w_max=8):
    fwd = jax.jit(lambda t: M.forward(params, cfg, tokens=t)[0][:, -1])
    topk, chain = build_bigram(fwd, cfg.vocab_size, k_max=k_max, w_max=w_max,
                               batch=cfg.vocab_size)
    uni = build_unigram(params["embed"]["embedding"],
                        params["embed"]["lm_head"], k_max=k_max)
    return NGramTables(uni, topk, chain)


# ---------------------------------------------------------------------------
# topology: static-layout invariants (fast lane)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("wdb", [(1, 1, 1), (2, 3, 1), (3, 2, 2), (2, 5, 2),
                                 (4, 4, 3), (3, 3, 5)])
def test_topology_counts_and_order(wdb):
    wd, dp, br = wdb
    topo = T.topology(wd, dp, br)
    d = T.effective_branch(dp, br)
    assert topo.num_nodes == T.num_nodes(wd, dp, br)
    assert topo.num_paths == T.num_paths(wd, dp, br) == wd ** d
    # level-major enumeration, 1-based levels, branch fan-out then chains
    lv = topo.level
    assert (np.diff(lv) >= 0).all() and lv[0] == 1 and lv[-1] == dp
    for lvl in range(1, dp + 1):
        expect = wd ** min(lvl, d)
        assert int((lv == lvl).sum()) == expect
    # spine nodes replay drafter rows: exactly width of them per level
    assert int(topo.spine.sum()) == wd * dp
    # each path's inputs start at the root and walk parent->child
    assert (topo.path_inputs[:, 0] == 0).all()
    for p in range(topo.num_paths):
        nodes = topo.path_nodes[p]
        assert topo.level[nodes[0]] == 1 and topo.parent[nodes[0]] == -1
        for j in range(1, dp):
            assert topo.parent[nodes[j]] == nodes[j - 1]
    # lex order of paths: path_max_branch of the all-0 path is 0
    assert topo.path_max_branch[0] == 0
    assert (topo.path_max_branch < wd).all()
    # query positions: root at offset 0, node at its level
    np.testing.assert_array_equal(topo.pos_off,
                                  np.concatenate([[0], topo.level]))


@pytest.mark.parametrize("wdb", [(2, 3, 2), (3, 2, 1), (2, 4, 4)])
def test_topology_ancestor_mask(wdb):
    """anc_mask makes each root-to-leaf path exactly a causal row: input i
    at level l sees precisely its l+1 ancestors-or-self (root included),
    and along any path the mask restricted to the path is lower-triangular."""
    topo = T.topology(*wdb)
    m = topo.anc_mask
    assert m[0].sum() == 1 and m[0, 0]
    for n in range(topo.num_nodes):
        assert int(m[n + 1].sum()) == int(topo.level[n]) + 1
    for p in range(topo.num_paths):
        ins = topo.path_inputs[p]
        sub = m[np.ix_(ins, ins)]
        np.testing.assert_array_equal(sub, np.tril(np.ones_like(sub)))
    # nothing sees a non-ancestor: siblings are mutually invisible
    for n in range(topo.num_nodes):
        s0 = int(topo.sibling0[n])
        if s0 != n:
            assert not m[n + 1, s0 + 1] and not m[s0 + 1, n + 1]


def test_topology_rejects_degenerate():
    for bad in [(0, 1, 1), (1, 0, 1), (1, 1, 0), (-1, 2, 2)]:
        with pytest.raises(ValueError):
            T.topology(*bad)


def test_fill_tree_spine_and_dedup():
    """Spine nodes replay the linear drafts verbatim (tree paths are a
    superset of the linear rows); off-spine children of a spine parent skip
    the candidate duplicating the spine continuation, so no branch level
    verifies the same token twice under one parent."""
    rng = np.random.default_rng(0)
    V, kmax, wd, dp, br = 13, 5, 3, 3, 2
    topo = T.topology(wd, dp, br)
    # bigram table with DISTINCT candidates per row (as build_bigram yields)
    big = np.stack([rng.permutation(V)[:kmax] for _ in range(V)])
    tables = NGramTables(jnp.zeros((kmax,), jnp.int32),
                         jnp.asarray(big, jnp.int32),
                         jnp.zeros((V,), jnp.int32))
    drafts = jnp.asarray(rng.integers(0, V, (2, wd, dp)), jnp.int32)
    toks = np.asarray(T.fill_tree(topo, drafts, tables))       # (B, N)
    for n in range(topo.num_nodes):
        if topo.spine[n]:
            np.testing.assert_array_equal(
                toks[:, n],
                np.asarray(drafts[:, topo.spine_row[n], topo.level[n] - 1]))
    # children of any one parent are pairwise distinct tokens
    for b in range(toks.shape[0]):
        for n in range(topo.num_nodes):
            sibs = [c for c in range(topo.num_nodes)
                    if topo.parent[c] == n]
            vals = [toks[b, c] for c in sibs]
            assert len(vals) == len(set(vals)), (b, n, vals)


def test_fill_tree_context_seeded_tails():
    """With the committed buffer provided, the chain tail below a deviation
    re-queries the buffer-local order-2 n-gram at its (grandparent, parent)
    pair and copies what followed; pairs never seen in the buffer fall back
    to the global bigram argmax, and spine nodes stay verbatim replays."""
    rng = np.random.default_rng(1)
    V, kmax, wd, dp, br = 13, 5, 2, 3, 2
    topo = T.topology(wd, dp, br)
    big = np.stack([rng.permutation(V)[:kmax] for _ in range(V)])
    tables = NGramTables(jnp.zeros((kmax,), jnp.int32),
                         jnp.asarray(big, jnp.int32),
                         jnp.zeros((V,), jnp.int32))
    drafts = jnp.asarray(rng.integers(0, V, (1, wd, dp)), jnp.int32)
    base = np.asarray(T.fill_tree(topo, drafts, tables))
    # find a level-2 deviation and its level-3 chain child
    dev = next(n for n in range(topo.num_nodes)
               if topo.level[n] == 2 and not topo.spine[n])
    tail = next(n for n in range(topo.num_nodes)
                if topo.parent[n] == dev)
    gp, p = base[0, topo.parent[dev]], base[0, dev]
    cont = (int(big[p][0]) + 1) % V          # any non-argmax continuation
    # buffer whose only (gp, p) occurrence is followed by `cont`
    buf = np.full((1, 16), (int(gp) + 1) % V, np.int32)
    buf[0, 3], buf[0, 4], buf[0, 5] = gp, p, cont
    seeded = np.asarray(T.fill_tree(
        topo, drafts, tables, buf=jnp.asarray(buf),
        buf_len=jnp.asarray([16], jnp.int32)))
    assert seeded[0, tail] == cont
    # same fill with a buffer that never saw the pair: bigram fallback
    unseen = np.asarray(T.fill_tree(
        topo, drafts, tables,
        buf=jnp.asarray(np.full((1, 16), (int(gp) + 1) % V, np.int32)),
        buf_len=jnp.asarray([16], jnp.int32)))
    assert unseen[0, tail] == big[p][0]
    np.testing.assert_array_equal(unseen, base)
    # spine nodes are untouched by seeding
    np.testing.assert_array_equal(seeded[:, topo.spine], base[:, topo.spine])
    # an occurrence whose continuation is PAST buf_len must not be used
    short = np.asarray(T.fill_tree(
        topo, drafts, tables, buf=jnp.asarray(buf),
        buf_len=jnp.asarray([5], jnp.int32)))    # pair at 3,4; cont at 5
    assert short[0, tail] == big[p][0]


def test_fill_tree_needs_wide_enough_tables():
    topo = T.topology(4, 2, 2)
    tables = NGramTables(jnp.zeros((2,), jnp.int32),
                         jnp.zeros((7, 2), jnp.int32),
                         jnp.zeros((7,), jnp.int32))
    with pytest.raises(ValueError, match="k_max"):
        T.fill_tree(topo, jnp.zeros((1, 4, 2), jnp.int32), tables)


def test_validate_tree_config_errors():
    with pytest.raises(ValueError):
        SpecConfig(strategy="greedy", tree=True).validate_tree()
    with pytest.raises(ValueError):
        SpecConfig(k=2, w=0, tree=True).validate_tree()
    with pytest.raises(ValueError):
        SpecConfig(k=2, w=2, tree=True, tree_branch=0).validate_tree()
    SpecConfig(k=2, w=2, tree=True).validate_tree()      # fine


# ---------------------------------------------------------------------------
# end-to-end: tree mode is bit-lossless vs greedy (slow model-level suite)
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("strategy", ["bigram", "unigram", "context",
                                      "mixed"])
def test_tree_generate_equals_greedy(tiny_dense, strategy):
    cfg, params = tiny_dense
    tables = _tables(params, cfg)
    B, P, N = 2, 10, 24
    prompt = jax.random.randint(jax.random.PRNGKey(5), (B, P), 0,
                                cfg.vocab_size)
    ref = greedy_reference(params, cfg, prompt, N)
    spec = SpecConfig(k=3, w=4, q=1, strategy=strategy, max_new_tokens=N,
                      tree=True, tree_branch=2)
    buf, blen, stats = generate(params, cfg, spec, prompt, tables)
    np.testing.assert_array_equal(np.asarray(buf[:, :P + N]),
                                  np.asarray(ref))
    assert (np.asarray(blen) == P + N).all()
    # rank histogram is per-PATH in tree mode
    assert stats["rank_hist"].shape[1] == T.num_paths(3, 4, 2)


@pytest.mark.slow
@pytest.mark.parametrize("wdb", [(1, 1, 1), (2, 3, 1), (4, 2, 2), (2, 5, 3)])
def test_tree_generate_shape_grid(tiny_dense, wdb):
    """Degenerate corners: single node, chain-only (branch beats depth),
    wide-shallow, branch > depth clamping — all lossless."""
    cfg, params = tiny_dense
    wd, dp, br = wdb
    tables = _tables(params, cfg, k_max=max(8, wd))
    B, P, N = 2, 6, 16
    prompt = jax.random.randint(jax.random.PRNGKey(7), (B, P), 0,
                                cfg.vocab_size)
    ref = greedy_reference(params, cfg, prompt, N)
    spec = SpecConfig(k=wd, w=dp, strategy="mixed", max_new_tokens=N,
                      tree=True, tree_branch=br)
    buf, _, _ = generate(params, cfg, spec, prompt, tables)
    np.testing.assert_array_equal(np.asarray(buf[:, :P + N]), np.asarray(ref))


@pytest.mark.slow
def test_tree_rejects_recurrent_arch(tiny_hybrid_cfg):
    cfg = tiny_hybrid_cfg
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tables = _tables(params, cfg)
    prompt = jnp.zeros((1, 4), jnp.int32)
    spec = SpecConfig(k=2, w=2, strategy="mixed", max_new_tokens=4,
                      tree=True)
    with pytest.raises(ValueError, match="attention-only"):
        generate(params, cfg, spec, prompt, tables)


# ---------------------------------------------------------------------------
# backend parity: tree-mask kernel path == XLA, both == greedy (fast subset
# runs in the backend-parity CI lane)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def parity_model():
    cfg = ModelConfig(name="tree-parity", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=61,
                      backend="xla", kernel_block_s=16, **F32).validate()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def parity_tables(parity_model):
    cfg, params = parity_model
    return _tables(params, cfg)


def test_tree_generate_backend_parity(parity_model, parity_tables):
    cfg, params = parity_model
    B, P, N = 2, 10, 14
    prompt = jax.random.randint(jax.random.PRNGKey(5), (B, P), 0,
                                cfg.vocab_size)
    ref = greedy_reference(params, cfg, prompt, N)
    outs = {}
    for backend in ("xla", "pallas"):
        c = dataclasses.replace(cfg, backend=backend).validate()
        spec = SpecConfig(k=3, w=3, strategy="mixed", max_new_tokens=N,
                          backend=backend, tree=True, tree_branch=2)
        buf, _, _ = generate(params, c, spec, prompt, parity_tables)
        outs[backend] = np.asarray(buf[:, :P + N])
    np.testing.assert_array_equal(outs["xla"], outs["pallas"])
    np.testing.assert_array_equal(outs["pallas"], np.asarray(ref))


@pytest.mark.parametrize("paged", [False, True])
def test_tree_kernel_mask_vs_ref(paged):
    """The bifurcated verify kernel under an arbitrary ancestor mask (as a
    lane-padded operand) matches the XLA oracle given the same mask — on
    both the linear-cache and paged grids."""
    from repro.kernels import ops
    topo = T.topology(2, 3, 2)
    KW1 = topo.num_nodes + 1                  # 11 — exercises lane padding
    B, H, KV, hd, S = 2, 4, 2, 16, 32
    rng = np.random.default_rng(3)
    r = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    q = r(B, 1, KW1, H, hd)
    kc, vc = r(B, S, KV, hd), r(B, S, KV, hd)
    kt, vt = r(B, 1, KW1, KV, hd), r(B, 1, KW1, KV, hd)
    cur = jnp.asarray([17, 9], jnp.int32)
    tm = tuple(map(tuple, topo.anc_mask.tolist()))
    want = ops.spec_attention_ref_op(q, kc, vc, kt, vt, cur, w1=KW1,
                                     tail_mask=tm)
    if paged:
        ps = 16
        pool_k = kc.reshape(B * (S // ps), ps, KV, hd)
        pool_v = vc.reshape(B * (S // ps), ps, KV, hd)
        pt = jnp.arange(B * (S // ps), dtype=jnp.int32).reshape(B, S // ps)
        got = ops.paged_spec_attention_op(q, pool_k, pool_v, pt, kt, vt,
                                          cur, w1=KW1, interpret=True,
                                          tail_mask=tm)
    else:
        got = ops.spec_attention_op(q, kc, vc, kt, vt, cur, w1=KW1,
                                    block_s=16, interpret=True, tail_mask=tm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# continuous serving + paged KV: admit/spec_step drive stays lossless
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("paged", [False, True])
def test_tree_continuous_serving_lossless(parity_model, parity_tables,
                                          paged):
    from repro.serving import ServingEngine
    cfg, params = parity_model
    prompts = ["tree serving", "paged or not", "third request"]

    def serve(tree):
        spec = SpecConfig(k=3, w=3, strategy="mixed", max_new_tokens=10,
                          tree=tree, tree_branch=2)
        eng = ServingEngine(params, cfg, spec, tables=parity_tables,
                            max_batch=2, buckets=(16,), max_new_cap=10,
                            bucket_align=1, paged=paged)
        for p in prompts:
            eng.submit(p, max_new_tokens=10)
        done = eng.serve_continuous()
        return {r.prompt: np.asarray(r.output_ids) for r in done}

    lin, tr = serve(False), serve(True)
    assert lin.keys() == tr.keys()
    for p in lin:
        np.testing.assert_array_equal(lin[p], tr[p], err_msg=p)


@pytest.mark.slow
def test_tree_continuous_reports_accept_hist(parity_model, parity_tables):
    from repro.serving import ServingEngine
    cfg, params = parity_model
    spec = SpecConfig(k=2, w=3, strategy="mixed", max_new_tokens=8,
                      tree=True, tree_branch=2)
    eng = ServingEngine(params, cfg, spec, tables=parity_tables,
                        max_batch=2, buckets=(16,), max_new_cap=8)
    eng.submit("histogram", max_new_tokens=8)
    (req,) = eng.serve_continuous()
    hist = req.stats["accept_hist"]
    assert len(hist) == spec.w + 2
    assert sum(hist) == req.stats["model_calls"]
    # the admission prefill commits the request's FIRST token outside any
    # spec_step, so the histogram accounts for every token but that one
    assert sum(i * c for i, c in enumerate(hist)) == \
        req.stats["new_tokens"] - 1


# ---------------------------------------------------------------------------
# masked tree arms: bit-parity with a dedicated static run per arm
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("arm", [(1, 1), (2, 2), (3, 4)])
def test_tree_masked_arm_equals_dedicated(tiny_dense, arm):
    """A (width_b, depth_b) tree arm masked inside the (width_max,
    depth_max) step must commit the SAME tokens in the SAME number of calls
    as a dedicated static run of that arm (DESIGN.md §11)."""
    cfg, params = tiny_dense
    tables = _tables(params, cfg)
    B, P, N = 2, 8, 16
    prompt = jax.random.randint(jax.random.PRNGKey(11), (B, P), 0,
                                cfg.vocab_size)
    kb, wb = arm

    def drive(spec):
        state = init_decode_state(params, cfg, spec, prompt)
        trail = []
        for _ in range(64):
            if not bool(np.asarray(~state.done).any()):
                break
            state = spec_step(params, cfg, spec, state, tables)
            trail.append(np.asarray(state.buf_len).copy())
        else:
            raise AssertionError("did not converge")
        return np.asarray(state.buf[:, :P + N]), trail

    # single-arm table: the bandit has no choice, every step is masked to it
    masked = SpecConfig(k=3, w=4, strategy="mixed", max_new_tokens=N,
                        tree=True, tree_branch=2, arms=((kb, wb),))
    dedicated = SpecConfig(k=kb, w=wb, strategy="mixed", max_new_tokens=N,
                           tree=True, tree_branch=2)
    out_m, trail_m = drive(masked)
    out_d, trail_d = drive(dedicated)
    np.testing.assert_array_equal(out_m, out_d)
    assert len(trail_m) == len(trail_d)
    for a, b in zip(trail_m, trail_d):          # call-by-call, not just final
        np.testing.assert_array_equal(a, b)


@pytest.mark.slow
def test_tree_adaptive_multi_arm_lossless(tiny_dense):
    """Whatever (width, depth) arms the bandit explores, output == greedy."""
    cfg, params = tiny_dense
    tables = _tables(params, cfg)
    B, P, N = 2, 8, 20
    prompt = jax.random.randint(jax.random.PRNGKey(13), (B, P), 0,
                                cfg.vocab_size)
    ref = greedy_reference(params, cfg, prompt, N)
    spec = SpecConfig(k=3, w=4, strategy="mixed", max_new_tokens=N,
                      tree=True, tree_branch=2,
                      arms=((1, 0), (2, 2), (3, 4)))
    buf, _, stats = generate(params, cfg, spec, prompt, tables)
    np.testing.assert_array_equal(np.asarray(buf[:, :P + N]),
                                  np.asarray(ref))
    assert int(np.asarray(stats["arm_pulls"]).sum()) > 0
