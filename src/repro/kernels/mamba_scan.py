"""Pallas TPU kernel: chunked Mamba selective scan (Jamba's SSM hot spot).

TPU adaptation of the paper-adjacent CUDA 'hardware-aware scan': the
(d_inner, d_state) recurrent state lives in VMEM scratch across sequence
chunks (the sequential grid axis), inputs stream chunk-by-chunk from HBM,
and the intra-chunk recurrence is a parallel ``associative_scan`` on the
VPU.  The state is never materialised for the full sequence in HBM — the
property that makes 32k-token Jamba prefill feasible.

Grid: (batch, d_inner/BD, T/C) with the chunk axis iterated sequentially.
VMEM per step: C*BD*DS*4 bytes for the scan intermediates (default
128*512*16*4 = 4 MiB) + the carried state BD*DS.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128
DEFAULT_BLOCK_D = 512


def _kernel(u_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, h0_ref,
            y_ref, hT_ref, h_scr):
    t = pl.program_id(2)
    n_t = pl.num_programs(2)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)          # (C, BD)
    dt = dt_ref[0].astype(jnp.float32)        # (C, BD)
    A = A_ref[...].astype(jnp.float32)        # (BD, DS)
    Bm = B_ref[0].astype(jnp.float32)         # (C, DS)
    Cm = C_ref[0].astype(jnp.float32)         # (C, DS)

    dA = jnp.exp(dt[..., None] * A[None])     # (C, BD, DS)
    dBx = (dt * u)[..., None] * Bm[:, None, :]

    def comb(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])

    cumA, hs = jax.lax.associative_scan(comb, (dA, dBx), axis=0)
    hs = hs + cumA * h_scr[...][None]
    y = jnp.einsum("cds,cs->cd", hs, Cm,
                   preferred_element_type=jnp.float32)
    y = y + u * D_ref[...].astype(jnp.float32)[None, :]
    y_ref[0] = y.astype(y_ref.dtype)
    h_scr[...] = hs[-1]

    @pl.when(t == n_t - 1)
    def _final():
        hT_ref[0] = h_scr[...].astype(hT_ref.dtype)


def mamba_scan_call(u, dt, A, B, C, D, h0, *, chunk: int = DEFAULT_CHUNK,
                    block_d: int = DEFAULT_BLOCK_D,
                    interpret: bool = False):
    """u/dt: (Bt, T, di) ; A: (di, ds) ; B/C: (Bt, T, ds) ; D: (di,) ;
    h0: (Bt, di, ds) f32.  Returns (y (Bt,T,di) f32, hT (Bt,di,ds) f32).

    T % chunk == 0 and di % block_d == 0 (ops.py pads/clamps).
    """
    Bt, T, di = u.shape
    ds = A.shape[1]
    assert T % chunk == 0 and di % block_d == 0, (T, chunk, di, block_d)
    grid = (Bt, di // block_d, T // chunk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, chunk, block_d), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((block_d, ds), lambda b, d, t: (d, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((block_d,), lambda b, d, t: (d,)),
            pl.BlockSpec((1, block_d, ds), lambda b, d, t: (b, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, block_d, ds), lambda b, d, t: (b, d, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((Bt, T, di), jnp.float32),
                   jax.ShapeDtypeStruct((Bt, di, ds), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((block_d, ds), jnp.float32)],
        interpret=interpret,
    )(u, dt, A, B, C, D, h0)
