"""repro-lint: static enforcement of the engine's lossless-speculation
contracts (DESIGN.md §13).

Two levels:

  - **Level 1 (jaxpr)** traces the real step/admit/release bodies on
    abstract states from a registry of representative serving configs and
    checks donation soundness, sharding coverage, trace-signature
    stability, and jitted-body host syncs.
  - **Level 2 (AST)** lints ``src/repro`` for repo-specific source rules:
    pallas-scope, tracer-branch, hash-constants, global-state,
    time-in-jit, plus the serving-loop host-sync inventory.

CLI: ``python -m repro.analysis [--strict] [--level {1,2}]
[--baseline PATH] [--syncmap PATH] [--json]``.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Tuple

from .findings import Baseline, Finding, apply_waivers, scan_waivers

PACKAGE_DIR = os.path.dirname(os.path.abspath(__file__))
SRC_ROOT = os.path.dirname(PACKAGE_DIR)          # .../src/repro
DEFAULT_BASELINE = os.path.join(PACKAGE_DIR, "baseline.json")

RULES: Dict[str, str] = {
    # level 1 (jaxpr)
    "donation": "donated DecodeState leaves alias outputs; no shared "
                "buffers between leaves",
    "sharding-coverage": "every DecodeState leaf has a strict "
                         "decode_state_pspec rule on every registry mesh",
    "trace-signature": "state signature is a fixed point of "
                       "step/admit/release (no per-iteration retrace)",
    "host-sync": "no host syncs in jitted bodies or un-waived syncs in "
                 "the serving critical path",
    # level 2 (AST)
    "pallas-scope": "pallas_call only inside kernels/",
    "tracer-branch": "no Python branching on jnp-derived values in core/",
    "hash-constants": "hash constants only from kernels/hashing",
    "global-state": "no module-level env/mesh mutation; install needs an "
                    "uninstall/activated pairing",
    "time-in-jit": "no wall-clock / host-RNG calls in jitted bodies",
}


def run_all(level: Optional[int] = None,
            src_root: str = SRC_ROOT) -> Tuple[List[Finding], List[Dict]]:
    """Run the requested level(s); returns (findings, host-sync inventory).

    Level 2 is pure AST work and imports nothing from the engine; Level 1
    imports jax and traces the registry, so it is lazily imported here to
    keep ``--level 2`` runnable in seconds anywhere.
    """
    findings: List[Finding] = []
    inventory: List[Dict] = []
    if level in (None, 2):
        from .ast_rules import run_level2
        got, inventory = run_level2(src_root)
        findings += got
    if level in (None, 1):
        from .jaxpr_rules import run_level1
        lvl1 = run_level1()
        findings += lvl1
        inventory += [{"file": f.file, "line": f.line, "method": "<jaxpr>",
                       "call": f.context, "kind": "jitted-body sync",
                       "code": f.message, "waived": f.waived,
                       "reason": f.waive_reason}
                      for f in lvl1 if f.rule == "host-sync"]
    return findings, inventory


__all__ = ["Baseline", "Finding", "RULES", "DEFAULT_BASELINE", "SRC_ROOT",
           "apply_waivers", "scan_waivers", "run_all"]
