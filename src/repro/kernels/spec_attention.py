"""Pallas TPU kernel: bifurcated speculative-verification attention.

This is the compute hot-spot of the paper's method: every decode step calls
the model on a (k, w+1) block whose attention reads an ell-long KV cache.
The paper's PyTorch layout replicates the cache k times (`torch.expand`);
on TPU we instead stream the SHARED cache once per (batch, head) from HBM
through VMEM (flash-decoding style online softmax over cache blocks) and
handle the per-row speculative tail with an in-register causal mask — the
k× HBM traffic disappears (DESIGN.md §3).

Layout/tiling:
  grid = (B, H, S/BS) — the last axis iterates sequentially on TPU, so the
  online-softmax accumulators live in VMEM scratch across cache blocks.
  q is laid out (B, H, K*W1, hd): K*W1 query rows per (batch, head); the MXU
  sees (K*W1, hd) x (hd, BS) matmuls — hd and BS should be multiples of 128
  (the ops.py wrapper pads).  cur_len is a scalar-prefetch operand so block
  masking is known before the DMA of each block.

The speculative tail (K*W1 keys) is processed in the LAST grid step with a
row-block-diagonal causal mask: query row i = (draft r_i, offset t_i) may
attend tail key j = (r_j, t_j) iff r_i == r_j and t_j <= t_i — drafts never
see each other, exactly the paper's batched independence.

Tree variant (DESIGN.md §11): tree-structured speculation verifies one
(N+1)-node token TREE per slot instead of k independent rows.  The only
kernel-visible difference is the tail mask: ancestor-only visibility
(``tail_mask[i, j]`` = input j is an ancestor-or-self of input i) replaces
the row-block-diagonal causal mask.  The mask is a static topology
constant; Pallas forbids capturing array constants in the kernel body, so
it rides as a tiny lane-padded int32 operand whose index map is constant —
the pipeline fetches the same (KW1, KW1) block once, not per cache block —
and the cache-streaming half is untouched: every tree node attends the
whole committed context exactly like a linear row.

Paged variant (DESIGN.md §8): the cache streaming is already block-shaped,
so the page-pool layout costs the kernel nothing — ``paged_spec_attention_call``
keeps the SAME kernel body and only swaps the cache index map: the pool is
(num_pages, KV, page_size, hd) with page_size == block_s, the per-slot page
table rides in as a second scalar-prefetch operand, and grid step s of batch
b DMAs physical page ``page_table[b, s]`` instead of linear block s.
Unallocated pages (-1) clamp to page 0; every position they cover is
>= cur_len, so the existing block mask hides them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 512
NEG_INF = -1e30
LANE = 128          # TPU lane width: the mask operand is lane-padded


def _pad_mask(tail_mask, kw1: int) -> np.ndarray:
    """(KW1, KW1) bool -> lane-padded (KW1, KW1p) int32 kernel operand."""
    tm = np.asarray(tail_mask, bool)
    assert tm.shape == (kw1, kw1), (tm.shape, kw1)
    kp = -(-kw1 // LANE) * LANE
    out = np.zeros((kw1, kp), np.int32)
    out[:, :kw1] = tm
    return out


def _kernel(cur_len_ref, q_ref, k_ref, v_ref, kt_ref, vt_ref, *rest,
            w1: int, scale: float, block_s: int, tree: bool = False):
    if tree:          # trailing operand: lane-padded int32 tail mask
        tm_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        tm_ref = None
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    s = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                  # (KW1, hd)
    kb = k_ref[0, 0].astype(jnp.float32)                 # (BS, hd)
    logits = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
    slot = s * block_s + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    valid = slot < cur_len_ref[b]
    logits = jnp.where(valid, logits, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_cur = jnp.max(logits, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1)
    vb = v_ref[0, 0].astype(jnp.float32)                 # (BS, hd)
    acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, vb, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc

    @pl.when(s == n_s - 1)
    def _tail_and_write():
        kt = kt_ref[0, 0].astype(jnp.float32)            # (KW1, hd)
        vt = vt_ref[0, 0].astype(jnp.float32)
        lt = jax.lax.dot_general(q, kt, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
        kw1 = lt.shape[0]
        if tm_ref is None:
            qi = jax.lax.broadcasted_iota(jnp.int32, (kw1, kw1), 0)
            kj = jax.lax.broadcasted_iota(jnp.int32, (kw1, kw1), 1)
            same_row = (qi // w1) == (kj // w1)
            causal = (kj % w1) <= (qi % w1)
            mask = same_row & causal
        else:
            # tree ancestor mask (DESIGN.md §11): constant-index-map block,
            # statically sliced back down from its lane padding
            mask = tm_ref[...][:, :kw1] != 0
        lt = jnp.where(mask, lt, NEG_INF)

        m_p, l_p, a_p = m_scr[...], l_scr[...], acc_scr[...]
        m_c = jnp.max(lt, axis=-1)
        m_f = jnp.maximum(m_p, m_c)
        p_t = jnp.exp(lt - m_f[:, None])
        alpha_f = jnp.exp(m_p - m_f)
        l_f = l_p * alpha_f + p_t.sum(axis=-1)
        a_f = a_p * alpha_f[:, None] + jax.lax.dot_general(
            p_t, vt, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_ref[0, 0] = (a_f / l_f[:, None]).astype(o_ref.dtype)


def spec_attention_call(q, k_cache, v_cache, k_tail, v_tail, cur_len, *,
                        w1: int, block_s: int = DEFAULT_BLOCK_S,
                        interpret: bool = False,
                        tail_mask=None) -> jnp.ndarray:
    """q: (B, H, KW1, hd) — KW1 = k*(w+1) rows, k-major.
    k_cache/v_cache: (B, KV, S, hd) (linear cache, slot == position).
    k_tail/v_tail:   (B, KV, KW1, hd) per-row speculative KV.
    cur_len: (B,) int32.  Returns (B, H, KW1, hd), dtype of q.

    ``tail_mask``: optional STATIC (KW1, KW1) bool replacing the
    row-block-diagonal causal tail mask — tree speculation passes the
    topology's ancestor mask here (DESIGN.md §11).

    S must be a multiple of block_s (ops.py pads).
    """
    B, H, KW1, hd = q.shape
    KV, S = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    assert S % block_s == 0, (S, block_s)
    assert KW1 % w1 == 0
    grid = (B, H, S // block_s)
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_kernel, w1=w1, scale=scale, block_s=block_s,
                               tree=tail_mask is not None)
    in_specs = [
        pl.BlockSpec((1, 1, KW1, hd), lambda b, h, s, c: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, block_s, hd),
                     lambda b, h, s, c: (b, h // G, s, 0)),
        pl.BlockSpec((1, 1, block_s, hd),
                     lambda b, h, s, c: (b, h // G, s, 0)),
        pl.BlockSpec((1, 1, KW1, hd),
                     lambda b, h, s, c: (b, h // G, 0, 0)),
        pl.BlockSpec((1, 1, KW1, hd),
                     lambda b, h, s, c: (b, h // G, 0, 0)),
    ]
    operands = [cur_len, q, k_cache, v_cache, k_tail, v_tail]
    if tail_mask is not None:
        tm = _pad_mask(tail_mask, KW1)
        in_specs.append(pl.BlockSpec(tm.shape, lambda b, h, s, c: (0, 0)))
        operands.append(tm)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, KW1, hd),
                                   lambda b, h, s, c: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((KW1,), jnp.float32),
                pltpu.VMEM((KW1,), jnp.float32),
                pltpu.VMEM((KW1, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, KW1, hd), q.dtype),
        interpret=interpret,
    )(*operands)


def _paged_kernel(cur_len_ref, pt_ref, *rest, **kw):
    # the page table steers DMA from the index maps only; the body is the
    # linear kernel unchanged (page s holds positions [s*ps, (s+1)*ps) of
    # its slot, exactly what the block mask assumes)
    return _kernel(cur_len_ref, *rest, **kw)


def paged_spec_attention_call(q, k_pool, v_pool, page_table, k_tail, v_tail,
                              cur_len, *, w1: int,
                              interpret: bool = False,
                              tail_mask=None) -> jnp.ndarray:
    """q: (B, H, KW1, hd); k_pool/v_pool: (num_pages, KV, page_size, hd);
    page_table: (B, pages_per_slot) int32, -1 = unallocated; tails/cur_len/
    tail_mask as in spec_attention_call.  block_s == page_size by
    construction, so the grid's cache axis walks the slot's page table:
    pages_per_slot steps per (batch, head), each DMA-ing one whole physical
    page.
    """
    B, H, KW1, hd = q.shape
    NP, KV, ps = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    PPS = page_table.shape[1]
    G = H // KV
    assert KW1 % w1 == 0
    grid = (B, H, PPS)
    scale = 1.0 / (hd ** 0.5)

    def page_ix(b, h, s, cl, pt):
        return (jnp.maximum(pt[b, s], 0), h // G, 0, 0)

    kernel = functools.partial(_paged_kernel, w1=w1, scale=scale, block_s=ps,
                               tree=tail_mask is not None)
    in_specs = [
        pl.BlockSpec((1, 1, KW1, hd),
                     lambda b, h, s, cl, pt: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, ps, hd), page_ix),
        pl.BlockSpec((1, 1, ps, hd), page_ix),
        pl.BlockSpec((1, 1, KW1, hd),
                     lambda b, h, s, cl, pt: (b, h // G, 0, 0)),
        pl.BlockSpec((1, 1, KW1, hd),
                     lambda b, h, s, cl, pt: (b, h // G, 0, 0)),
    ]
    operands = [cur_len, page_table.astype(jnp.int32), q, k_pool, v_pool,
                k_tail, v_tail]
    if tail_mask is not None:
        tm = _pad_mask(tail_mask, KW1)
        in_specs.append(pl.BlockSpec(tm.shape,
                                     lambda b, h, s, cl, pt: (0, 0)))
        operands.append(tm)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, KW1, hd),
                                   lambda b, h, s, cl, pt: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((KW1,), jnp.float32),
                pltpu.VMEM((KW1,), jnp.float32),
                pltpu.VMEM((KW1, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, KW1, hd), q.dtype),
        interpret=interpret,
    )(*operands)
