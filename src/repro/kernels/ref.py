"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .hashing import hash_step


def spec_attention_ref(q, k_cache, v_cache, k_tail, v_tail, cur_len, *,
                       w1: int, tail_mask=None) -> jnp.ndarray:
    """Same contract as spec_attention_call, computed densely in f32.

    q: (B,H,KW1,hd); k/v_cache: (B,KV,S,hd); k/v_tail: (B,KV,KW1,hd);
    cur_len: (B,).  ``tail_mask``: optional static (KW1, KW1) bool tail
    visibility (tree ancestor mask) replacing the per-row causal mask.
    """
    B, H, KW1, hd = q.shape
    KV, S = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, G, KW1, hd)
    scale = 1.0 / (hd ** 0.5)
    lc = jnp.einsum("bngqh,bnsh->bngqs", qf,
                    k_cache.astype(jnp.float32)) * scale
    valid = (jnp.arange(S)[None, :] < cur_len[:, None])
    lc = jnp.where(valid[:, None, None, None, :], lc, -1e30)
    lt = jnp.einsum("bngqh,bnth->bngqt", qf,
                    k_tail.astype(jnp.float32)) * scale
    if tail_mask is None:
        qi = jnp.arange(KW1)
        same_row = (qi[:, None] // w1) == (qi[None, :] // w1)
        causal = (qi[None, :] % w1) <= (qi[:, None] % w1)
        tail_mask = same_row & causal
    lt = jnp.where(jnp.asarray(tail_mask, bool), lt, -1e30)
    logits = jnp.concatenate([lc, lt], axis=-1)
    w = jax.nn.softmax(logits, axis=-1)
    out = (jnp.einsum("bngqs,bnsh->bngqh", w[..., :S],
                      v_cache.astype(jnp.float32))
           + jnp.einsum("bngqt,bnth->bngqh", w[..., S:],
                        v_tail.astype(jnp.float32)))
    return out.reshape(B, H, KW1, hd).astype(q.dtype)


def ngram_match_ref(buf_padded: jnp.ndarray, query: jnp.ndarray,
                    cur_len: jnp.ndarray, *, w: int):
    """Oracle for ngram_match_call. buf_padded: (L+q+w,); returns ((L,), (L,))."""
    q = query.shape[0]
    L = buf_padded.shape[0] - q - w
    pos = jnp.arange(L)
    match = jnp.ones((L,), bool)
    for j in range(q):
        match = match & (buf_padded[j:j + L] == query[j])
    match = match & (pos + q + w <= cur_len[0])
    h = jnp.zeros((L,), jnp.uint32)
    for j in range(w):
        h = hash_step(h, buf_padded[q + j:q + j + L])
    return match.astype(jnp.int32), h


def mamba_scan_ref(u, dt, A, B, C, D, h0):
    """Oracle for mamba_scan_call: sequential recurrence in f32."""
    uf, dtf = u.astype(jnp.float32), dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, xs):
        u_t, dt_t, b_t, c_t = xs
        dA = jnp.exp(dt_t[..., None] * Af)              # (Bt, di, ds)
        h = dA * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in
               (uf, dtf, B.astype(jnp.float32), C.astype(jnp.float32)))
    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1) + uf * D.astype(jnp.float32)
    return y, hT
