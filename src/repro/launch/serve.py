"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Loads (or quickly trains) a model, builds the learning-free tables from its
own weights, then serves a batch of prompts with batched speculation and
reports tokens/call + wall time vs the greedy baseline.
"""
from __future__ import annotations

import argparse

from repro.launch import hostdev

if __name__ == "__main__":
    # --mesh needs placeholder devices BEFORE the jax import below locks
    # the count (appends to XLA_FLAGS; respects a caller-provided count)
    hostdev.ensure_for_mesh_argv()

import jax

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.core.spec_engine import SpecConfig
from repro.data.datasets import make_prompts
from repro.serving import ServingEngine
from repro.train import AdamWConfig, init_train_state, make_train_step
from repro.train.checkpoint import load


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, default="mistral-7b")
    ap.add_argument("--ckpt", default="", help="params npz (else quick-train)")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--w", type=int, default=10)
    ap.add_argument("--strategy", default="mixed",
                    choices=["mixed", "bigram", "unigram", "context",
                             "greedy"])
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--n-prompts", type=int, default=4)
    ap.add_argument("--task", default="code", choices=["code", "math",
                                                       "chat"])
    ap.add_argument("--continuous", action="store_true",
                    help="serve with slot-level continuous batching instead "
                         "of static batches")
    ap.add_argument("--adaptive", action="store_true",
                    help="pick (k, w) online with the UCB controller "
                         "instead of the static --k/--w: per batch under "
                         "static serving, per slot per step (shape-stable "
                         "arm masking inside the one jitted spec_step, "
                         "DESIGN.md §9) under --continuous")
    ap.add_argument("--tree", action="store_true",
                    help="tree-structured speculation (DESIGN.md §11): "
                         "branch on the top --k candidates at the first "
                         "--tree-branch depths, verify the whole token tree "
                         "in ONE ancestor-masked forward call; bit-identical "
                         "outputs, attention-only archs")
    ap.add_argument("--tree-branch", type=int, default=2,
                    help="number of branching levels in the draft tree "
                         "(deeper levels chain greedily); only with --tree")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache for continuous batching: slots "
                         "share a page pool with per-slot page tables "
                         "(DESIGN.md §8) instead of worst-case linear "
                         "buffers; bit-identical outputs")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="page-pool size for --paged (0 = linear worst "
                         "case; smaller pools defer admission when "
                         "exhausted)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="positions per page for --paged (0 = the verify "
                         "kernel's cache block)")
    ap.add_argument("--mesh", default="",
                    help="serve SHARDED over a DxM debug mesh (e.g. 2x2 = "
                         "data 2 x model 2; 3 dims add a leading pod axis). "
                         "On CPU the launcher forces placeholder devices "
                         "via XLA_FLAGS when none are configured; outputs "
                         "stay bit-identical to unsharded serving "
                         "(DESIGN.md §10)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for every submitted request "
                         "(0 = greedy, bit-exact spec path; > 0 serves "
                         "losslessly via rejection-verified speculative "
                         "sampling inside the same spec_step, DESIGN.md "
                         "§12)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus mass for --temperature > 0 (1 = off)")
    ap.add_argument("--seed", type=int, default=0,
                    help="engine rng seed: request keys derive from it, so "
                         "a rerun with the same seed replays the same "
                         "sampled outputs")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "xla", "pallas"],
                    help="kernel-dispatch backend (kernels/dispatch.py): "
                         "auto = pallas on TPU, xla elsewhere; pallas "
                         "off-TPU runs in interpret mode (slow, parity "
                         "checking only)")
    args = ap.parse_args()
    if args.paged and not args.continuous:
        raise SystemExit("--paged applies to --continuous serving")
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(hostdev.parse_mesh_shape(args.mesh))

    cfg = get_smoke_config(args.arch)
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch}: encoder-only arch has no decode loop")
    import dataclasses
    cfg = dataclasses.replace(cfg, vocab_size=max(cfg.vocab_size, 259),
                              backend=args.backend)
    ts = init_train_state(jax.random.PRNGKey(0), cfg)
    params = ts["params"]
    if args.ckpt:
        params = load(args.ckpt, params)
    else:
        import jax.numpy as jnp

        from repro.data.pipeline import mixed_batches
        print("quick-training the smoke model (pass --ckpt to skip)...")
        step = jax.jit(make_train_step(cfg, AdamWConfig(
            lr=1e-3, total_steps=80, warmup_steps=8), remat=False))
        for b in mixed_batches(8, 128, 80):
            ts, m = step(ts, jnp.asarray(b))
        params = ts["params"]
        print(f"  final loss {float(m['loss']):.3f}")

    spec = SpecConfig(k=args.k, w=args.w, strategy=args.strategy,
                      max_new_tokens=args.max_new, backend=args.backend,
                      tree=args.tree, tree_branch=args.tree_branch)
    eng = ServingEngine(params, cfg, spec, max_batch=args.n_prompts,
                        max_new_cap=args.max_new, adaptive=args.adaptive,
                        paged=args.paged,
                        num_pages=args.num_pages or None,
                        page_size=args.page_size, mesh=mesh,
                        sampling=args.temperature > 0 or None,
                        seed=args.seed)
    for prompt, _ in make_prompts(args.task, args.n_prompts):
        eng.submit(prompt, max_new_tokens=args.max_new,
                   temperature=args.temperature, top_p=args.top_p)
    served = eng.serve_continuous() if args.continuous else eng.serve_all()
    for r in served:
        if "error" in r.stats:
            print(f"[req {r.request_id}] REJECTED: {r.stats['error']}")
            continue
        print(f"[req {r.request_id}] tokens/call="
              f"{r.stats['tokens_per_call']:.2f} "
              f"calls={r.stats['model_calls']} "
              f"output={r.output[:60]!r}")
    if args.paged:
        print(f"pool: {eng.pool_stats()}")
    if args.adaptive and args.continuous:
        print(f"bandit: {eng.adaptive_stats()}")
    if mesh is not None:
        rep = eng.mesh_report()
        print(f"mesh: {rep.get('mesh')} params sharded "
              f"{rep.get('params_sharded')}/{rep.get('params_leaves')} "
              f"state leaves sharded {rep.get('state_sharded', 'n/a')} "
              f"fallbacks {rep.get('replication_fallbacks')}")


if __name__ == "__main__":
    main()
