"""Process-wide execution flags.

UNROLL_FOR_ANALYSIS: XLA's HloCostAnalysis counts a while-loop body ONCE, so
flops/bytes/collectives of scan-over-layers programs are undercounted by the
trip count.  The dry-run's roofline calibration sets this flag and compiles
two REDUCED-depth variants (1 and 2 pattern periods) with every scan
replaced by an unrolled python loop / single-chunk form, then extrapolates
linearly in depth (benchmarks/roofline.py).  Production lowering always
keeps the compact scans.

Exception that remains scanned even here: the sLSTM time recurrence (it is
inherently sequential, xLSTM paper §2); its flops are corrected analytically
in the roofline.
"""
UNROLL_FOR_ANALYSIS = False


def set_unroll(v: bool) -> None:
    global UNROLL_FOR_ANALYSIS
    UNROLL_FOR_ANALYSIS = v
