"""Launchers: mesh construction, dry-run, train/serve entry points.

NOTE: launch/dryrun.py must be executed as a MODULE ENTRY POINT
(``python -m repro.launch.dryrun``): it sets XLA_FLAGS before importing jax.
Importing this package does NOT touch device state.
"""
