"""Train a ~30M-param model on the synthetic corpus and watch speculation
quality improve as the model sharpens (tokens/call rises with training).

Run:  PYTHONPATH=src python examples/train_tiny.py [--steps 300]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core.spec_engine import SpecConfig, generate
from repro.data.pipeline import mixed_batches
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.core.ngram_tables import NGramTables, build_bigram, build_unigram
from repro.train import AdamWConfig, init_train_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
args = ap.parse_args()

cfg = ModelConfig(name="tiny-30m", num_layers=4, d_model=256, num_heads=8,
                  num_kv_heads=4, d_ff=1024, vocab_size=259,
                  param_dtype=jnp.float32, compute_dtype=jnp.float32)
print(f"params: {cfg.param_count():,}")
ts = init_train_state(jax.random.PRNGKey(0), cfg)
step = jax.jit(make_train_step(cfg, AdamWConfig(
    lr=6e-4, total_steps=args.steps, warmup_steps=args.steps // 10)))

tok = ByteTokenizer()
prompt = jnp.asarray(tok.encode_batch(["def mul_numbers(a, b):\n"], 24))

def tokens_per_call(params):
    fwd = jax.jit(lambda t: M.forward(params, cfg, tokens=t)[0][:, -1])
    topk, chain = build_bigram(fwd, cfg.vocab_size, k_max=10, w_max=10)
    uni = build_unigram(params["embed"]["embedding"],
                        params["embed"]["lm_head"], k_max=10)
    tables = NGramTables(uni, topk, chain)
    spec = SpecConfig(k=10, w=10, strategy="mixed", max_new_tokens=48)
    _, _, stats = generate(params, cfg, spec, prompt, tables)
    return float(stats["tokens"][0]) / max(int(stats["calls"][0]), 1)

it = mixed_batches(8, 128, args.steps)
for i, b in enumerate(it):
    ts, m = step(ts, jnp.asarray(b))
    if (i + 1) % max(args.steps // 3, 1) == 0:
        tpc = tokens_per_call(ts["params"])
        print(f"step {i+1:4d}: loss={float(m['loss']):.3f} "
              f"-> tokens/call={tpc:.2f}")
