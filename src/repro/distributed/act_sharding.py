"""Activation-sharding constraints (Megatron-SP style), installable hook.

Model code is mesh-agnostic; the launcher installs a sharder before lowering
and the transformer calls ``constrain(x, kind)`` at the few points GSPMD
propagation needs help:

  - "residual": the (B, T, d) stream carried between blocks (and the remat
    checkpoint!): batch over ("pod","data"), sequence over "model"
    (sequence-parallelism — the all-gather to full T happens inside each
    block's first matmul, its reduce-scatter at the block output; XLA inserts
    these automatically from the constraint).
  - "logits": (B, Tc, V) loss chunks: vocab over "model".

Without this, Nemotron-340B train activations lower replicated over the
model axis: 864 GiB/device temp (measured) vs ~56 GiB/device after
(EXPERIMENTS.md §Perf it-1).
"""
from __future__ import annotations

import contextlib
from typing import Iterator, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .sharding import resolve_axis

_MESH: Optional[Mesh] = None


def _batch(mesh: Mesh, b: int):
    """Activation batch dims replicate legitimately when odd (a 3-row
    partial batch is routine, not a mis-sized mesh): resolve quietly,
    never through the ShardingFallbackWarning path."""
    return resolve_axis(mesh, "embed", b, warn=False)


def install(mesh: Optional[Mesh]) -> None:
    """Set the process-global activation sharder.  Prefer ``activated`` —
    a bare install leaks the mesh across engines/tests, and an installed
    mesh pins ``attn_verify`` off the Pallas kernel path
    (models/attention.py:_use_verify_kernel)."""
    global _MESH
    _MESH = mesh


def uninstall() -> None:
    install(None)


def installed() -> bool:
    return _MESH is not None


@contextlib.contextmanager
def activated(mesh: Optional[Mesh]) -> Iterator[None]:
    """Scoped install: the sharder is active inside the block and the
    PREVIOUS value is restored on exit (exception-safe), so one engine's
    mesh can never leak into another engine's traces.  ``constrain`` only
    matters at trace time, so owners (ServingEngine, the dry-run) wrap
    every call that may trace in this context instead of installing
    globally.  ``activated(None)`` is a no-op scope."""
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield
    finally:
        _MESH = prev


def constrain(x, kind: str):
    if _MESH is None:
        return x
    mesh = _MESH
    if kind == "residual" and x.ndim == 3:
        B, T, _ = x.shape
        # sequence parallelism is opportunistic (decode-time T = w+1 is
        # tiny and legitimately replicated): no fallback warning here
        spec = P(_batch(mesh, B),
                 resolve_axis(mesh, "heads", T, warn=False), None)
    elif kind == "logits" and x.ndim == 3:
        B, T, V = x.shape
        spec = P(_batch(mesh, B), None,
                 resolve_axis(mesh, "vocab", V))
    elif kind == "ctx_logits" and x.ndim == 6:
        # decode/verify context logits (B, K, n_kv, G, w1, S): keep them in
        # the CACHE's sharding (kv heads over "model" when divisible, else
        # cache sequence over "model") so the big KV cache is never
        # all-gathered — the tiny q block is re-sharded instead, and the
        # softmax/value contraction pay only small partial-reduce
        # collectives (flash-decode sequence parallelism, §Perf it-7).
        B, K, n_kv, G, w1, S = x.shape
        n_ax = resolve_axis(mesh, "kv", n_kv, warn=False)  # seq fallback next
        s_ax = None
        if n_ax is None and S % mesh.shape.get("model", 1) == 0:
            s_ax = "model"
        spec = P(_batch(mesh, B), None, n_ax, None, None,
                 s_ax)
    elif kind == "ctx_out" and x.ndim == 6:
        # (B, K, w1, n_kv, G, hd) value-contraction output: batch-only so
        # the s-sharded contraction resolves as partial-sum + small
        # all-reduce instead of all-gathering the V cache.
        spec = P(_batch(mesh, x.shape[0]), None, None, None,
                 None, None)
    elif kind == "hidden_ffn" and x.ndim >= 2:
        spec = P(*([_batch(mesh, x.shape[0])]
                   + [None] * (x.ndim - 2)
                   + [resolve_axis(mesh, "ffn", x.shape[-1])]))
    else:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
