"""Paged KV cache (DESIGN.md §8): layout parity, free-list hygiene, serving.

Contract under test:
  (a) free-list/page-table unit behaviour — alloc/free/grow keep the pool
      partitioned (no double-mapped page, no leak), including heavy
      admit/release churn (fragmentation);
  (b) the paged Pallas kernel equals the linear kernel on the gathered
      linear view, bit for bit (interpret mode on CPU);
  (c) ``generate()`` and ``ServingEngine.step()`` are bit-identical between
      the linear and paged layouts for every strategy, and between the xla
      and pallas backends on the paged layout;
  (d) a pool-limited long-context arrival mix that linear worst-case sizing
      could not fit completes under paged serving with zero leaked pages.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ngram_tables import NGramTables, build_bigram, build_unigram
from repro.core.spec_engine import (PagedConfig, SpecConfig, admit_slot,
                                    empty_decode_state, generate,
                                    greedy_reference, release_slot, spec_step)
from repro.kernels import ops
from repro.models import cache as C
from repro.models import model as M
from repro.models.config import BlockSpec, ModelConfig
from repro.serving import ServingEngine

F32 = dict(param_dtype=jnp.float32, compute_dtype=jnp.float32)
PS = 16  # page size everywhere below: small enough that tiny decodes
         # cross page boundaries and exercise on-the-fly growth


@pytest.fixture(scope="module")
def paged_model():
    cfg = ModelConfig(name="paged", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=61,
                      backend="xla", kernel_block_s=PS, **F32).validate()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def paged_tables(paged_model):
    cfg, params = paged_model
    fwd = jax.jit(lambda t: M.forward(params, cfg, tokens=t)[0][:, -1])
    topk, chain = build_bigram(fwd, cfg.vocab_size, k_max=8, w_max=8,
                               batch=cfg.vocab_size)
    uni = build_unigram(params["embed"]["embedding"],
                        params["embed"]["lm_head"], k_max=8)
    return NGramTables(uni, topk, chain)


# ---------------------------------------------------------------------------
# (a) free-list / page-table unit behaviour
# ---------------------------------------------------------------------------
def _unit_state(paged_model, batch=3, num_pages=10, pps=5):
    cfg, _ = paged_model
    return C.init_paged_state(cfg, batch, num_pages, PS, pps)


def test_alloc_free_invariants(paged_model):
    st = _unit_state(paged_model)
    st = C.alloc_slot_pages(st, jnp.int32(0), 2)
    st = C.alloc_slot_pages(st, jnp.int32(1), 3)
    C.check_page_invariants(st)
    assert int(st["free_top"]) == 5
    st = C.free_slot_pages(st, jnp.int32(0))
    C.check_page_invariants(st)
    st = C.free_slot_pages(st, jnp.int32(0))     # idempotent double free
    C.check_page_invariants(st)
    assert int(st["free_top"]) == 7
    st = C.free_slot_pages(st, jnp.int32(1))
    assert int(st["free_top"]) == 10


def test_grow_pages_batched(paged_model):
    st = _unit_state(paged_model)
    st = C.grow_pages(st, jnp.asarray([3 * PS, PS + 1, 9]),
                      jnp.asarray([True, True, False]))
    np.testing.assert_array_equal(np.asarray(st["n_pages"]), [3, 2, 0])
    C.check_page_invariants(st)
    # growth is incremental: already-covered rows take nothing
    st2 = C.grow_pages(st, jnp.asarray([3 * PS, PS + 1, 9]),
                       jnp.asarray([True, True, True]))
    np.testing.assert_array_equal(np.asarray(st2["n_pages"]), [3, 2, 1])
    C.check_page_invariants(st2)


def test_phys_slots_sentinel(paged_model):
    st = _unit_state(paged_model, batch=1, num_pages=4, pps=3)
    st = C.alloc_slot_pages(st, jnp.int32(0), 2)
    pt = np.asarray(st["page_table"])[0]
    pos = jnp.asarray([[0, PS - 1, PS, 2 * PS, -1, 99]])
    ph = np.asarray(C.phys_slots(st["page_table"], pos, PS, 4))
    assert ph[0, 0] == pt[0] * PS
    assert ph[0, 1] == pt[0] * PS + PS - 1
    assert ph[0, 2] == pt[1] * PS
    assert ph[0, 3] == 4 * PS        # unallocated page -> OOB sentinel
    assert ph[0, 4] == 4 * PS        # negative position -> OOB sentinel
    assert ph[0, 5] == 4 * PS        # beyond the table  -> OOB sentinel


def test_fragmentation_churn_no_leak(paged_model):
    """Many interleaved alloc/grow/free cycles leave the free list exactly
    partitioning the pool (the page table gets arbitrarily scrambled —
    that fragmentation is the layout's normal operating state)."""
    rng = np.random.default_rng(0)
    st = _unit_state(paged_model, batch=4, num_pages=24, pps=6)
    live = {}
    for it in range(200):
        slot = int(rng.integers(0, 4))
        if slot in live and rng.random() < 0.5:
            st = C.free_slot_pages(st, jnp.int32(slot))
            del live[slot]
        elif slot not in live:
            n = int(rng.integers(1, 4))
            free = int(np.asarray(st["free_top"]))
            if free >= n:
                st = C.alloc_slot_pages(st, jnp.int32(slot), n)
                live[slot] = n
        else:                       # grow the live slot by one page
            want = (live[slot] + 1) * PS
            if int(np.asarray(st["free_top"])) >= 1 and live[slot] < 6:
                act = jnp.arange(4) == slot
                st = C.grow_pages(st, jnp.full((4,), want), act)
                live[slot] += 1
        C.check_page_invariants(st)
    for slot in list(live):
        st = C.free_slot_pages(st, jnp.int32(slot))
    C.check_page_invariants(st)
    assert int(st["free_top"]) == 24, "leaked pages after churn"


# ---------------------------------------------------------------------------
# (b) paged kernel == linear kernel on the gathered view
# ---------------------------------------------------------------------------
def test_paged_kernel_matches_linear_gather():
    rng = np.random.default_rng(0)
    B, K, W1, H, KV, hd, NP, PPS = 2, 3, 4, 4, 2, 16, 12, 4
    sh = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    q, kt, vt = sh(B, K, W1, H, hd), sh(B, K, W1, KV, hd), sh(B, K, W1, KV, hd)
    kp, vp = sh(NP, PS, KV, hd), sh(NP, PS, KV, hd)
    pt = jnp.asarray([[5, 2, 9, -1], [0, 7, -1, -1]], jnp.int32)
    cur = jnp.asarray([3 * PS - 2, PS + 5], jnp.int32)
    pid = jnp.clip(pt, 0, NP - 1)
    k_lin = kp[pid].reshape(B, PPS * PS, KV, hd)
    v_lin = vp[pid].reshape(B, PPS * PS, KV, hd)
    lin = ops.spec_attention_op(q, k_lin, v_lin, kt, vt, cur, w1=W1,
                                block_s=PS, interpret=True)
    paged = ops.paged_spec_attention_op(q, kp, vp, pt, kt, vt, cur, w1=W1,
                                        interpret=True)
    np.testing.assert_array_equal(np.asarray(lin), np.asarray(paged))
    ref = ops.spec_attention_ref_op(q, k_lin, v_lin, kt, vt, cur, w1=W1)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# (c) generate() / step() parity: linear vs paged, xla vs pallas
# ---------------------------------------------------------------------------
STRATEGIES = ["greedy", "bigram", "unigram", "context", "mixed"]


@pytest.mark.slow
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_generate_parity_linear_vs_paged(paged_model, paged_tables, strategy):
    cfg, params = paged_model
    B, P, N = 2, 10, 20
    prompt = jax.random.randint(jax.random.PRNGKey(5), (B, P), 0,
                                cfg.vocab_size)
    spec = SpecConfig(k=4, w=3, q=1, strategy=strategy, max_new_tokens=N)
    buf_l, len_l, stats_l = generate(params, cfg, spec, prompt, paged_tables)
    buf_p, len_p, stats_p = generate(params, cfg, spec, prompt, paged_tables,
                                     paged=PagedConfig(page_size=PS))
    np.testing.assert_array_equal(np.asarray(len_l), np.asarray(len_p))
    n = P + N
    np.testing.assert_array_equal(np.asarray(buf_l[:, :n]),
                                  np.asarray(buf_p[:, :n]))
    for key in stats_l:
        np.testing.assert_array_equal(np.asarray(stats_l[key]),
                                      np.asarray(stats_p[key]),
                                      err_msg=f"stats[{key}]")
    ref = greedy_reference(params, cfg, prompt, N)
    np.testing.assert_array_equal(np.asarray(buf_p[:, :n]), np.asarray(ref))


@pytest.mark.parametrize("strategy", ["context", "mixed"])
def test_generate_paged_backend_parity(paged_model, paged_tables, strategy):
    """xla vs pallas-interpret on the PAGED layout, bit for bit."""
    cfg, params = paged_model
    B, P, N = 2, 10, 16
    prompt = jax.random.randint(jax.random.PRNGKey(7), (B, P), 0,
                                cfg.vocab_size)
    outs = {}
    for backend in ("xla", "pallas"):
        c = dataclasses.replace(cfg, backend=backend).validate()
        spec = SpecConfig(k=3, w=3, q=1, strategy=strategy, max_new_tokens=N,
                          backend=backend)
        buf, blen, _ = generate(params, c, spec, prompt, paged_tables,
                                paged=PagedConfig(page_size=PS))
        assert (np.asarray(blen) == P + N).all()
        outs[backend] = np.asarray(buf[:, :P + N])
    np.testing.assert_array_equal(outs["xla"], outs["pallas"])
    ref = greedy_reference(params, cfg, prompt, N)
    np.testing.assert_array_equal(outs["pallas"], np.asarray(ref))


def test_paged_generate_hybrid_arch():
    """Paged pool + gated-replay commit: attention layer inside a recurrent
    (Jamba-pattern) stack, pallas backend."""
    cfg = ModelConfig(
        name="hyb-paged", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=61,
        block_pattern=(BlockSpec("mamba", "swiglu"),
                       BlockSpec("attn", "swiglu")),
        backend="pallas", kernel_block_s=PS, **F32).validate()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, P, N = 2, 8, 10
    prompt = jax.random.randint(jax.random.PRNGKey(5), (B, P), 0,
                                cfg.vocab_size)
    ref = greedy_reference(params, cfg, prompt, N)
    spec = SpecConfig(k=3, w=3, strategy="context", max_new_tokens=N,
                      backend="pallas")
    buf, _, _ = generate(params, cfg, spec, prompt, None,
                         paged=PagedConfig(page_size=PS))
    np.testing.assert_array_equal(np.asarray(buf[:, :P + N]),
                                  np.asarray(ref))


@pytest.mark.slow
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_step_parity_linear_vs_paged(paged_model, paged_tables, strategy):
    """ServingEngine.step() (admit -> spec_step -> retire, staggered
    arrivals) returns identical per-request outputs in both layouts."""
    cfg, params = paged_model
    spec = SpecConfig(k=4, w=3, q=1, strategy=strategy, max_new_tokens=12)
    tables = paged_tables if strategy != "greedy" else None
    outs = {}
    for mode in ("linear", "paged"):
        eng = ServingEngine(params, cfg, spec, tables=tables, max_batch=2,
                            buckets=(16,), max_new_cap=12, bucket_align=1,
                            paged=(mode == "paged"), page_size=PS)
        r1 = eng.submit("layout parity", max_new_tokens=12)
        r2 = eng.submit("one step behind", max_new_tokens=7)
        eng.step()
        r3 = eng.submit("late arrival", max_new_tokens=9)
        done = eng.serve_continuous()
        assert sorted(r.request_id for r in done) == \
            sorted(r.request_id for r in (r1, r2, r3))
        outs[mode] = {r.prompt: np.asarray(r.output_ids) for r in done}
        if mode == "paged":
            C.check_page_invariants(eng._cont_state.model)
            assert eng.pool_stats()["free_pages"] == \
                eng.pool_stats()["num_pages"], "pages leaked after drain"
    for prompt in outs["linear"]:
        np.testing.assert_array_equal(outs["linear"][prompt],
                                      outs["paged"][prompt], err_msg=prompt)


@pytest.mark.slow
def test_step_paged_backend_parity(paged_model, paged_tables):
    """xla vs pallas-interpret through the paged ServingEngine.step()."""
    cfg, params = paged_model
    outs = {}
    for backend in ("xla", "pallas"):
        c = dataclasses.replace(cfg, backend=backend).validate()
        spec = SpecConfig(k=3, w=3, strategy="mixed", max_new_tokens=10,
                          backend=backend)
        eng = ServingEngine(params, c, spec, tables=paged_tables,
                            max_batch=2, buckets=(16,), max_new_cap=10,
                            bucket_align=1, paged=True, page_size=PS)
        eng.submit("backend parity", max_new_tokens=10)
        eng.submit("second row", max_new_tokens=8)
        done = eng.serve_continuous()
        outs[backend] = {r.prompt: np.asarray(r.output_ids) for r in done}
    for prompt in outs["xla"]:
        np.testing.assert_array_equal(outs["xla"][prompt],
                                      outs["pallas"][prompt], err_msg=prompt)


# ---------------------------------------------------------------------------
# (d) pool-limited serving: long context among shorts, churn, no leaks
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_long_context_coexists_in_pool_linear_could_not_fit(paged_model,
                                                            paged_tables):
    """One long-context request rides with a stream of short ones in a pool
    SMALLER than linear worst-case sizing (which charges every slot the
    long request's buffer), with bit-correct outputs and zero leaks."""
    cfg, params = paged_model
    spec = SpecConfig(k=4, w=3, strategy="mixed", max_new_tokens=8)
    max_batch, long_bucket, short_bucket, cap = 3, 64, 16, 8
    # linear worst case: every slot pays the long bucket
    linear_pages = max_batch * int(
        C.pages_for_len(long_bucket + cap + spec.w + 2, PS))
    num_pages = linear_pages - 5
    eng = ServingEngine(params, cfg, spec, tables=paged_tables,
                        max_batch=max_batch, buckets=(short_bucket,
                                                      long_bucket),
                        max_new_cap=cap, bucket_align=1, paged=True,
                        page_size=PS, num_pages=num_pages)
    long_req = eng.submit("L" * 40, max_new_tokens=cap)    # 64-bucket
    shorts = [eng.submit(f"short {i}", max_new_tokens=cap)
              for i in range(6)]
    done = eng.serve_continuous()
    stats = eng.pool_stats()
    assert stats["num_pages"] < linear_pages
    assert stats["peak_pages"] <= stats["num_pages"]
    assert stats["free_pages"] == stats["num_pages"], "leaked pages"
    assert stats["rejected"] == 0
    C.check_page_invariants(eng._cont_state.model)
    assert len(done) == 7
    # outputs match per-request references (pool pressure never corrupts)
    for req in [long_req] + shorts:
        got = next(r for r in done if r.request_id == req.request_id)
        padded = eng.scheduler.pad_to_bucket(eng.tok.encode(req.prompt))[None]
        ref = greedy_reference(params, cfg, jnp.asarray(padded), cap)
        np.testing.assert_array_equal(
            got.output_ids, np.asarray(ref[0, padded.shape[1]:]),
            err_msg=req.prompt)


@pytest.mark.slow
def test_serving_churn_no_page_leak(paged_model, paged_tables):
    """Slot-reuse churn (3 waves through 2 slots) returns every page."""
    cfg, params = paged_model
    spec = SpecConfig(k=3, w=3, strategy="mixed", max_new_tokens=6)
    eng = ServingEngine(params, cfg, spec, tables=paged_tables, max_batch=2,
                        buckets=(16,), max_new_cap=6, bucket_align=1,
                        paged=True, page_size=PS)
    for wave in range(3):
        for i in range(2):
            eng.submit(f"wave {wave} req {i}", max_new_tokens=6)
        done = eng.serve_continuous()
        assert len(done) == 2
        C.check_page_invariants(eng._cont_state.model)
        st = eng.pool_stats()
        assert st["free_pages"] == st["num_pages"], f"leak after wave {wave}"
