"""Sharding-rule unit tests (no multi-device backend needed: rules are pure
functions of mesh *shape*; we build a Mesh over 1 real device is impossible
for 16x16, so we test the PartitionSpec logic through a fake mesh object)."""
import dataclasses

import jax.numpy as jnp
import pytest

from repro.distributed import sharding as shd
from repro.models.config import BlockSpec, ModelConfig


class FakeMesh:
    """Duck-typed stand-in: the rules only read ``mesh.shape``."""

    def __init__(self, shape_dict):
        self.shape = shape_dict


POD = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_resolve_axis_divisibility_fallback():
    assert shd.resolve_axis(POD, "kv", 8) is None          # 8 % 16 != 0
    assert shd.resolve_axis(POD, "kv", 32) == "model"
    assert shd.resolve_axis(POD, "embed", 4096) == "data"
    assert shd.resolve_axis(MULTI, "embed", 4096) == ("pod", "data")
    assert shd.resolve_axis(MULTI, "embed", 16) == "data"  # 16 % 32 != 0
    assert shd.resolve_axis(POD, None, 123) is None


class _Leaf:
    def __init__(self, shape):
        self.shape = shape


class _K:
    def __init__(self, key):
        self.key = key


def test_param_pspec_attention():
    spec = shd.param_pspec(POD, (_K("p0"), _K("mixer"), _K("wq")),
                           _Leaf((32, 4096, 8192)))
    assert tuple(spec) == (None, "data", "model")
    # kv proj with kv*hd=1024 divisible
    spec = shd.param_pspec(POD, (_K("p0"), _K("mixer"), _K("wk")),
                           _Leaf((32, 4096, 1024)))
    assert tuple(spec) == (None, "data", "model")


def test_param_pspec_moe_expert_fallback():
    # 16 experts: shard expert dim
    spec = shd.param_pspec(POD, (_K("p1"), _K("mlp"), _K("w_gate")),
                           _Leaf((9, 16, 8192, 24576)))
    assert tuple(spec) == (None, "model", "data", None)
    # 8 experts (mixtral): not divisible -> shard ffn instead
    spec = shd.param_pspec(POD, (_K("p0"), _K("mlp"), _K("w_gate")),
                           _Leaf((32, 8, 4096, 14336)))
    assert tuple(spec) == (None, None, "data", "model")


def test_state_pspec_kv_cache():
    # kv=8 not divisible by model=16 -> shard the cache SEQUENCE (it-5)
    spec = shd.state_pspec(POD, (_K("groups"), _K("p0"), _K("k")),
                           _Leaf((32, 128, 32768, 8, 128)))
    assert tuple(spec) == (None, "data", "model", None, None)
    # kv=32 divisible
    spec = shd.state_pspec(POD, (_K("groups"), _K("p0"), _K("k")),
                           _Leaf((24, 128, 32768, 32, 64)))
    assert tuple(spec) == (None, "data", None, "model", None)
    # batch=1 (long_500k), kv non-divisible: seq goes to "model"
    spec = shd.state_pspec(POD, (_K("groups"), _K("p0"), _K("k")),
                           _Leaf((32, 1, 8192, 8, 128)))
    assert tuple(spec) == (None, None, "model", None, None)


def test_state_pspec_recurrent():
    spec = shd.state_pspec(POD, (_K("groups"), _K("p0"), _K("ssm")),
                           _Leaf((63, 128, 16384, 16)))
    assert tuple(spec) == (None, "data", "model", None)
    # mlstm C: nh=4 not divisible -> shard dh
    spec = shd.state_pspec(POD, (_K("groups"), _K("p0"), _K("C")),
                           _Leaf((9, 32, 4, 384, 384)))
    assert tuple(spec) == (None, "data", None, "model", None)


def test_every_assigned_arch_has_full_param_coverage():
    """Every leaf of every assigned arch gets a VALID PartitionSpec (rank
    matches) under both meshes — rule gaps would silently replicate."""
    import jax

    from repro.configs import ALL_ARCHS, get_config
    from repro.models import model as M
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda r: M.init_params(r, cfg),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        for mesh in (POD, MULTI):
            for path, leaf in jax.tree_util.tree_flatten_with_path(
                    shapes)[0]:
                spec = shd.param_pspec(mesh, path, leaf)
                assert len(spec) == len(leaf.shape), (arch, path)
                # spec axes must divide the dim
                for ax, d in zip(spec, leaf.shape):
                    if ax is None:
                        continue
                    axes = (ax,) if isinstance(ax, str) else ax
                    size = 1
                    for a in axes:
                        size *= mesh.shape[a]
                    assert d % size == 0, (arch, path, spec, leaf.shape)
