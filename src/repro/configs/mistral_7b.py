"""Mistral-7B — the paper's own evaluation model [arXiv:2310.06825]."""
import jax.numpy as jnp
from ..models.config import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-7b", arch_type="dense", source="arXiv:2310.06825",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=32000,
        block_pattern=(BlockSpec("attn", "swiglu"),),
        norm="rmsnorm", rope="rope", rope_theta=1e6,
        sliding_window=4096,
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-7b-smoke", arch_type="dense", source="arXiv:2310.06825",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=512,
        block_pattern=(BlockSpec("attn", "swiglu"),),
        norm="rmsnorm", rope="rope", rope_theta=1e6, sliding_window=64,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    ).validate()
