"""Sharded-vs-single-device bit parity for LIVE serving over a real mesh
(DESIGN.md §10).

The contract: a ``ServingEngine(mesh=...)`` — params placed by
``params_shardings``, DecodeState by ``decode_state_shardings``, the
activation sharder scoped to the engine's own traces — produces BIT-
IDENTICAL token streams to the same engine without a mesh, for one-shot
``generate()`` and the continuous ``admit_slot``/``spec_step`` drive,
across every drafting strategy, over the linear and the paged KV layout,
compiling the sharded step exactly once.

This module needs placeholder devices: jax locks the device count at first
init, so the flag must precede interpreter-wide jax import — run it in its
OWN process (the CI ``sharded`` lane):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_sharded_serving.py

Under the plain tier-1 run (1 CPU device) everything here skips.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

NEEDED_DEVICES = 4

pytestmark = pytest.mark.skipif(
    jax.device_count() < NEEDED_DEVICES,
    reason="sharded lane: run with XLA_FLAGS="
           "--xla_force_host_platform_device_count=8 in a fresh process")

from repro.core import spec_engine                              # noqa: E402
from repro.core.ngram_tables import (NGramTables, build_bigram,  # noqa: E402
                                     build_unigram)
from repro.core.spec_engine import SpecConfig                   # noqa: E402
from repro.distributed import act_sharding                      # noqa: E402
from repro.distributed import sharding as shd                   # noqa: E402
from repro.kernels import ops                                   # noqa: E402
from repro.launch.mesh import make_debug_mesh                   # noqa: E402
from repro.models import model as M                             # noqa: E402
from repro.models.config import ModelConfig                     # noqa: E402
from repro.serving import ServingEngine                         # noqa: E402

F32 = dict(param_dtype=jnp.float32, compute_dtype=jnp.float32)

PROMPTS = [("hello world", 16), ("a rather different prompt", 12),
           ("third request!", 16), ("four", 9), ("five arrives late", 16)]


@pytest.fixture(scope="module")
def model():
    cfg = ModelConfig(name="mesh-tiny", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=61,
                      **F32).validate()
    return cfg, M.init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def tables(model):
    cfg, params = model
    fwd = jax.jit(lambda t: M.forward(params, cfg, tokens=t)[0][:, -1])
    topk, chain = build_bigram(fwd, cfg.vocab_size, k_max=8, w_max=8,
                               batch=cfg.vocab_size)
    uni = build_unigram(params["embed"]["embedding"],
                        params["embed"]["lm_head"], k_max=8)
    return NGramTables(uni, topk, chain)


@pytest.fixture(scope="module")
def mesh22():
    return make_debug_mesh((2, 2))


def _spec(strategy):
    return SpecConfig(k=4, w=3, strategy=strategy, max_new_tokens=16)


def _engine(model, tables, spec, mesh, **kw):
    cfg, params = model
    return ServingEngine(params, cfg, spec,
                         tables=tables if spec.strategy != "greedy" else None,
                         max_batch=4, buckets=(16,), max_new_cap=16,
                         mesh=mesh, **kw)


def _serve(eng, mode="continuous", prompts=PROMPTS):
    reqs = [eng.submit(p, max_new_tokens=m) for p, m in prompts]
    done = eng.serve_continuous() if mode == "continuous" else eng.serve_all()
    by_id = {r.request_id: r for r in done}
    assert sorted(by_id) == sorted(r.request_id for r in reqs)
    return [by_id[r.request_id] for r in reqs]


def _assert_parity(plain, meshed):
    for a, b in zip(plain, meshed):
        np.testing.assert_array_equal(a.output_ids, b.output_ids,
                                      err_msg=a.prompt)
        assert a.stats["new_tokens"] == b.stats["new_tokens"]
        assert a.stats["model_calls"] == b.stats["model_calls"]


# ---------------------------------------------------------------------------
# generate(): sharded serve_all == single-device serve_all, every strategy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", ["greedy", "bigram", "unigram",
                                      "context", "mixed"])
def test_generate_sharded_parity(model, tables, mesh22, strategy):
    plain = _serve(_engine(model, tables, _spec(strategy), None),
                   mode="static")
    meshed = _serve(_engine(model, tables, _spec(strategy), mesh22),
                    mode="static")
    _assert_parity(plain, meshed)
    assert not act_sharding.installed(), "engine leaked its mesh globally"


# ---------------------------------------------------------------------------
# continuous admit/step drive: every strategy (linear), mixed+greedy (paged)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", ["greedy", "bigram", "unigram",
                                      "context", "mixed"])
def test_continuous_sharded_parity(model, tables, mesh22, strategy):
    plain = _serve(_engine(model, tables, _spec(strategy), None))
    meshed = _serve(_engine(model, tables, _spec(strategy), mesh22))
    _assert_parity(plain, meshed)
    assert not act_sharding.installed()


@pytest.mark.parametrize("strategy", ["greedy", "mixed"])
def test_continuous_sharded_parity_paged(model, tables, mesh22, strategy):
    """The paged pool under a mesh: the pool's page axis shards like the
    sequence axis (decode_state_pspec) and outputs stay bit-identical."""
    kw = dict(paged=True, page_size=8)
    plain = _serve(_engine(model, tables, _spec(strategy), None, **kw))
    meshed = _serve(_engine(model, tables, _spec(strategy), mesh22, **kw))
    _assert_parity(plain, meshed)


def test_adaptive_sharded_parity(model, tables, mesh22):
    """In-flight adaptive (k, w) arm masking composes with the mesh: the
    per-slot bandit state rides the sharded DecodeState.stats."""
    arms = ((1, 0), (2, 2), (4, 3))
    kw = dict(adaptive=True, arms=arms)
    spec = _spec("mixed")
    plain = _serve(_engine(model, tables, spec, None, **kw))
    meshed = _serve(_engine(model, tables, spec, mesh22, **kw))
    _assert_parity(plain, meshed)
    for r in meshed:
        assert sum(r.stats["arm_pulls"].values()) == r.stats["model_calls"]


# ---------------------------------------------------------------------------
# one trace under the mesh: NamedSharding-pinned outputs keep the state's
# placement a fixed point, so step N+1 never re-lowers
# ---------------------------------------------------------------------------
def test_sharded_step_single_trace_with_donation(model, tables, mesh22,
                                                 monkeypatch):
    import warnings as W
    cfg, params = model
    cfg = dataclasses.replace(cfg, name="mesh-spy").validate()  # fresh jit
    traces = {"n": 0}
    real = spec_engine._step_body

    def spy(*a, **k):
        traces["n"] += 1
        return real(*a, **k)

    monkeypatch.setattr(spec_engine, "_step_body", spy)
    eng = _engine((cfg, params), tables, _spec("mixed"), mesh22)
    with W.catch_warnings(record=True) as caught:
        W.simplefilter("always")
        done = _serve(eng)
    assert all(r.stats["new_tokens"] > 0 for r in done)
    assert traces["n"] == 1, (
        f"sharded spec_step traced {traces['n']} times — the state's "
        f"sharding is not a fixed point of the step (donation/out_shardings "
        f"drift forces per-step recompiles)")
    # donation must survive NamedSharding: jax warns when a donated buffer
    # could not be aliased into the output (sharding mismatch = copies of
    # the whole KV cache every step)
    donation_leaks = [str(w.message) for w in caught
                      if "donated" in str(w.message).lower()]
    assert not donation_leaks, donation_leaks


# ---------------------------------------------------------------------------
# mesh-state hygiene: a meshed engine must not pin LATER engines off the
# Pallas-eligible path (the act_sharding global-leak regression)
# ---------------------------------------------------------------------------
def test_meshed_then_plain_engine_keeps_pallas_path(model, tables, mesh22,
                                                    monkeypatch):
    cfg, params = model
    _serve(_engine(model, tables, _spec("mixed"), mesh22))      # uses mesh
    assert not act_sharding.installed()
    hits = {"attn": 0}
    real_attn = ops.spec_attention_op

    def spy(*a, **k):
        hits["attn"] += 1
        return real_attn(*a, **k)

    monkeypatch.setattr(ops, "spec_attention_op", spy)
    cfg_p = dataclasses.replace(cfg, name="mesh-then-pallas",
                                backend="pallas",
                                kernel_block_s=16).validate()
    plain = _serve(_engine((cfg_p, params), tables, _spec("mixed"), None),
                   prompts=PROMPTS[:2])
    assert hits["attn"] > 0, (
        "a previously-built meshed engine left the activation sharder "
        "installed: the plain engine fell off the Pallas verify kernel")
    assert all(r.stats["new_tokens"] > 0 for r in plain)


def test_mesh_pins_xla_backend_with_warning(model, tables, mesh22):
    """The documented dispatch seam: backend='pallas' under a mesh warns
    and serves on the sharded XLA path (never reaches the kernel)."""
    cfg, params = model
    cfg_p = dataclasses.replace(cfg, name="mesh-pallas-seam",
                                backend="pallas",
                                kernel_block_s=16).validate()
    with pytest.warns(UserWarning, match="pins the Pallas kernels"):
        eng = _engine((cfg_p, params), tables, _spec("mixed"), mesh22)
    done = _serve(eng, prompts=PROMPTS[:2])
    assert all(r.stats["new_tokens"] > 0 for r in done)
    assert eng.mesh_report()["backend"] == "xla"


# ---------------------------------------------------------------------------
# the mesh_report must prove the state actually sharded
# ---------------------------------------------------------------------------
def test_mesh_report_shows_sharded_state(model, tables, mesh22):
    eng = _engine(model, tables, _spec("mixed"), mesh22)
    _serve(eng, prompts=PROMPTS[:2])
    rep = eng.mesh_report()
    assert rep["mesh"] == {"data": 2, "model": 2}
    assert rep["params_sharded"] > 0
    specs = rep["state_specs"]
    assert "'data'" in specs["buf"]                  # slots over data
    assert "'data'" in specs["model/groups/p0/k"]    # cache batch over data
    assert "'model'" in specs["model/groups/p0/k"]   # kv heads over model
    assert rep["state_sharded"] >= 3
    # vocab 61 divides nothing on a (2,2) mesh: the replication fallback
    # must be SURFACED, not silent
    assert ["vocab", 61] in rep["replication_fallbacks"]


def test_paged_pool_sharded_and_free_list_replicated(model, tables, mesh22):
    eng = _engine(model, tables, _spec("mixed"), mesh22, paged=True,
                  page_size=8)
    _serve(eng, prompts=PROMPTS[:2])
    specs = eng.mesh_report()["state_specs"]
    pool = specs["model/groups/p0/k"]
    assert "'data'" in pool or "'model'" in pool     # page axis / kv sharded
    assert specs["model/free_list"] == "(None,)"
    assert "'data'" in specs["model/page_table"]
    pool_stats = eng.pool_stats()
    assert pool_stats["free_pages"] == pool_stats["num_pages"]  # no leaks


# ---------------------------------------------------------------------------
# property: ANY debug-mesh shape whose axes divide (B, S) serves lossless
# ---------------------------------------------------------------------------
def test_any_dividing_mesh_shape_is_lossless(model, tables):
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    shapes = [s for s in [(1, 2), (2, 1), (2, 2), (4, 1), (1, 4), (4, 2),
                          (2, 4)]
              if s[0] * s[1] <= jax.device_count()]
    plain = _serve(_engine(model, tables, _spec("mixed"), None),
                   prompts=PROMPTS[:3])

    @settings(max_examples=4, deadline=None,
              suppress_health_check=[hypothesis.HealthCheck.too_slow])
    @given(shape=st.sampled_from(shapes))
    def check(shape):
        meshed = _serve(_engine(model, tables, _spec("mixed"),
                                make_debug_mesh(shape)),
                        prompts=PROMPTS[:3])
        _assert_parity(plain, meshed)

    check()
