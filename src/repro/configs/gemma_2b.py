"""Gemma-2B: GeGLU, head_dim=256, MQA (kv=1), tied + scaled embeddings
[arXiv:2403.08295]."""
import jax.numpy as jnp
from ..models.config import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b", arch_type="dense", source="arXiv:2403.08295",
        num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
        head_dim=256, d_ff=16384, vocab_size=256000,
        block_pattern=(BlockSpec("attn", "geglu"),),
        norm="rmsnorm", rope="rope",
        tie_embeddings=True, scale_embed=True,
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma-smoke", arch_type="dense", source="arXiv:2403.08295",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=1,
        head_dim=64, d_ff=256, vocab_size=512,
        block_pattern=(BlockSpec("attn", "geglu"),),
        norm="rmsnorm", rope="rope",
        tie_embeddings=True, scale_embed=True,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    ).validate()
