"""The speculative generation engine: draft -> verify -> accept -> commit.

The unit of work is ONE jitted iteration, ``spec_step``: it drafts, runs the
batched verification call, and commits the winning tokens for every *active*
slot of a persistent ``DecodeState`` pytree.  Everything is fixed-shape (a
requirement for TPU serving): the token buffer is static-length, per-sequence
progress is tracked by ``buf_len``, and inactive/finished rows simply commit
0 tokens.

``generate`` (the one-shot path) is a thin ``lax.while_loop`` over the same
step body, so batch-at-once generation and step-driven serving are literally
the same computation — the bit-exact-vs-greedy guarantee (property-tested)
transfers to both.  Step-driven serving additionally gets ``admit_slot`` /
``release_slot`` so a continuous-batching engine can retire finished rows and
prefill a queued prompt into the freed slot *between* verify calls
(serving/engine.py builds on exactly this).

Invariants:
  - output is bit-identical to greedy decoding (property-tested);
  - per row: model.cur_len == #cached positions == buf_len - 1 (the last
    committed token's KV is materialised by the *next* call, exactly as in
    the paper's Appendix D cache).

Commit paths:
  - attention-only archs: write the winner's verified KV tail (no extra
    model call) — ``commit_kv_tails``;
  - archs with recurrent mixers (Jamba, xLSTM): gated replay of the winner
    row (one (B, w+1) forward; ~1/k of the verify cost) — see DESIGN.md §4.

Statistics mirror the paper's ablations (Fig. 4): acceptance-length
histogram, winning-rank histogram, context/bigram allocation and
per-strategy accepted tokens.  Stats are per-slot; ``admit_slot`` zeroes a
slot's row so a continuous engine reads them per-request at retirement.

In-flight adaptive (k, w) (DESIGN.md §9): ``SpecConfig.arms`` turns (k, w)
into compile-time maxima and every step each slot picks one arm by
per-slot UCB (core/controller.py) and is MASKED down to it — bit-identical
to a dedicated static step of that arm, with zero recompiles across arm
switches.  The bandit's (B, A) state rides in ``DecodeState.stats`` and is
zeroed with the rest of the slot's stats on admission/release.

Lossless speculative sampling (DESIGN.md §12): ``SpecConfig.sampling``
compiles the sampled verification walk into the same step — per-slot
``temperature``/``top_p``/``rng_key`` DecodeState leaves steer each row at
runtime, 0-temperature rows stay bit-exact greedy, and temperature > 0 rows
emit exactly the plain-sampling output distribution (the point-mass
rejection rule realised by trajectory coupling — core/verify.py).  One
compiled step therefore serves mixed greedy/sampled continuous batches.

Tree mode (DESIGN.md §11): ``SpecConfig.tree`` swaps the k independent
linear rows for ONE token tree per slot (core/tree.py): the first
``min(tree_branch, w)`` depths branch over the drafter's top-k candidates,
deeper levels chain on argmax, and the whole tree is verified in a single
(B, 1, N+1) call whose attention uses the topology's static ancestor mask.
Acceptance runs over the tree's root-to-leaf PATHS (each bit-identical to a
linear row of the same tokens), the winning path's KV tail is gathered and
committed through the unchanged ``commit_kv_tails``.  Under ``arms`` the
(k, w) pairs read as (tree_width, depth) arms, masked by path eligibility
(all branch indices < width_b) — the same zero-recompile contract as §9.
Attention-only archs only: recurrent mixers verify rows as causal
sequences, which has no valid tree layout (validate_tree raises).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import dispatch
from ..models import cache as C
from ..models import model as M
from ..models.config import ModelConfig
from . import tree as T
from .controller import (arm_slowdowns, choose_arms, init_arm_stats,
                         tree_arm_slowdowns, update_arm_stats)
from .drafters import (bigram_draft, context_ngram_draft, mixed_draft,
                       multi_depth_draft, unigram_draft)
from .ngram_tables import NGramTables
from .verify import accept, per_row_keys, sample_predictions, sample_token


@dataclasses.dataclass(frozen=True)
class PagedConfig:
    """Sizing of a paged DecodeState (models/cache.py, DESIGN.md §8).

    ``num_pages`` is the page-pool size shared by every slot; 0 sizes it to
    the per-slot worst case (num_slots * pages_per_slot — the linear
    footprint, useful for parity testing).  ``page_size`` is positions per
    page; 0 follows the verify kernel's cache block (cfg.kernel_block_s or
    the kernel default), which keeps the paged Pallas grid page-aligned.
    """
    num_pages: int = 0
    page_size: int = 0

    def resolve_page_size(self, cfg: ModelConfig) -> int:
        return self.page_size or C.default_page_size(cfg)


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    k: int = 10                 # number of batched drafts
    w: int = 10                 # speculation depth
    q: int = 1                  # context-match query length
    strategy: str = "mixed"     # mixed | bigram | unigram | context | greedy
    max_new_tokens: int = 64
    eos_id: int = -1            # -1: never stop on eos
    # drafter backend (kernels/dispatch.py): "xla" | "pallas" | "auto" —
    # routes the context match/hash sweep to the Pallas kernel or XLA.
    # (The verify call's backend is ModelConfig.backend: it lives in the
    # model, not the drafter.)
    backend: str = "auto"
    # In-flight adaptive (k, w) (DESIGN.md §9): a static table of
    # (k_arm, w_arm) arms, each within [1, k] x [0, w].  When set, (k, w)
    # become the COMPILE-TIME maxima of the step's shapes; every step each
    # slot picks one arm by per-slot UCB (core/controller.py) and is masked
    # down to it — bit-identical to a dedicated (k_arm, w_arm) step, with
    # zero recompiles across arm switches.  (k_arm, w_arm) == (1, 0) is
    # plain greedy decoding.  The per-slot bandit state lives in
    # DecodeState.stats and is zeroed on slot admission/release.
    arms: Optional[Tuple[Tuple[int, int], ...]] = None
    adapt_explore: float = 0.3  # UCB exploration coefficient
    adapt_ema: float = 0.9      # per-arm tokens-per-call EMA decay
    adapt_ell: int = 512        # context length of the roofline prior
    # Tree mode (DESIGN.md §11): verify one top-k draft TREE per slot
    # instead of k independent rows.  (k, w) read as (tree width, depth);
    # ``tree_branch`` is how many of the first depths fan out over the
    # drafter's top-k candidates (deeper levels argmax-chain).  Under
    # ``arms`` the arm table reads as (width, depth) pairs in the same
    # [1, k] x [0, w] box.  Attention-only archs, tables required.
    tree: bool = False
    tree_branch: int = 2
    # Lossless speculative sampling (DESIGN.md §12): compile the sampled
    # verification walk (core/verify.py::sample_predictions) into the step.
    # Per-slot temperature/top_p/rng_key leaves in DecodeState then steer
    # each row at RUNTIME — temperature == 0 rows stay bit-exact greedy, so
    # one compiled step serves mixed greedy/sampled batches.  Off by
    # default: the gumbel draw + top-p sort are real per-step work that
    # pure-greedy serving should not pay, and the flag is static so the
    # greedy-only executable is byte-identical to the pre-sampling engine.
    sampling: bool = False

    def validate_tree(self) -> "SpecConfig":
        """Raise unless the tree knobs are a buildable topology."""
        if not self.tree:
            return self
        if self.strategy == "greedy":
            raise ValueError("tree mode needs a drafting strategy "
                             "(strategy='greedy' verifies nothing)")
        if self.w < 1:
            raise ValueError(f"tree mode needs w >= 1, got w={self.w}")
        if self.tree_branch < 1:
            raise ValueError(
                f"tree_branch must be >= 1, got {self.tree_branch}")
        return self

    def validate_arms(self) -> "SpecConfig":
        """Raise unless the arm table fits the compile-time (k, w) box."""
        if self.arms is None:
            return self
        if self.strategy == "greedy":
            raise ValueError(
                "arms require a drafting strategy (the greedy arm (1, 0) "
                "is expressed inside the masked step, not via "
                "strategy='greedy')")
        if not self.arms:
            raise ValueError("arms must be a non-empty tuple")
        for a in self.arms:
            ka, wa = a
            if not (1 <= ka <= self.k and 0 <= wa <= self.w):
                raise ValueError(
                    f"arm {a} outside the compile-time box "
                    f"[1, {self.k}] x [0, {self.w}]")
        return self


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["buf", "buf_len", "prompt_len", "budget", "eos_id", "done",
                 "active", "model", "stats", "rng_key", "temperature",
                 "top_p"],
    meta_fields=[])
@dataclasses.dataclass
class DecodeState:
    """Persistent decoding state: one row ("slot") per in-flight sequence.

    A slot is *occupied* while ``active``; ``done`` marks rows that must not
    commit further tokens (finished, or empty slot).  ``eos_id == -1`` means
    the row never stops on eos.  All leaves are fixed-shape so the state can
    thread through ``lax.while_loop`` and a jit-compiled ``spec_step``
    without recompilation as requests come and go.

    Sampling leaves (DESIGN.md §12): ``rng_key`` is the slot's CARRY key —
    a sampling-enabled step splits it once (vmapped over slots, inside the
    jit, donated with the rest of the state), uses one half for this step's
    gumbel draws and stores the other, so replaying the same admitted key
    replays the same output.  ``temperature``/``top_p`` are per-slot runtime
    data: 0-temperature rows take the bit-exact argmax path inside the SAME
    compiled step.  All three reset on admit_slot/release_slot exactly like
    the bandit stats.
    """
    buf: jnp.ndarray         # (B, L) int32 token buffer (prompt + output)
    buf_len: jnp.ndarray     # (B,) int32 committed length per row
    prompt_len: jnp.ndarray  # (B,) int32
    budget: jnp.ndarray      # (B,) int32 per-row max_new_tokens
    eos_id: jnp.ndarray      # (B,) int32 per-row eos (-1: never)
    done: jnp.ndarray        # (B,) bool
    active: jnp.ndarray      # (B,) bool — slot currently occupied
    model: Dict[str, Any]    # models/cache.py state {"cur_len", "groups"}
    stats: Dict[str, jnp.ndarray]
    rng_key: jnp.ndarray = None      # (B, 2) uint32 per-slot carry key
    temperature: jnp.ndarray = None  # (B,) f32, <= 0 -> greedy row
    top_p: jnp.ndarray = None        # (B,) f32 nucleus mass, 1 -> off

    @property
    def num_slots(self) -> int:
        return self.buf.shape[0]

    @property
    def buf_size(self) -> int:
        return self.buf.shape[1]


def _draft(spec: SpecConfig, tables: NGramTables, buf, buf_len, last):
    if spec.strategy == "mixed":
        return mixed_draft(tables, buf, buf_len, last, spec.q, spec.k,
                           spec.w, backend=spec.backend)
    if spec.strategy == "bigram":
        d, v = bigram_draft(tables, last, spec.k, spec.w)
    elif spec.strategy == "unigram":
        d, v = unigram_draft(tables, buf.shape[0], spec.k, spec.w)
    elif spec.strategy == "context":
        d, v = context_ngram_draft(buf, buf_len, spec.q, spec.k, spec.w,
                                   backend=spec.backend)
        d = jnp.where(v[..., None], d, 0)
    else:
        raise ValueError(spec.strategy)
    n_ctx = (v.sum(axis=1) if spec.strategy == "context"
             else jnp.zeros((buf.shape[0],), jnp.int32))
    return d, v, n_ctx.astype(jnp.int32)


def _init_stats(spec: SpecConfig, B: int) -> Dict[str, jnp.ndarray]:
    # tree mode ranks over root-to-leaf PATHS, not drafter rows
    ranks = (T.num_paths(spec.k, spec.w, spec.tree_branch) if spec.tree
             else max(spec.k, 1))
    st = {
        "calls": jnp.zeros((B,), jnp.int32),
        "tokens": jnp.zeros((B,), jnp.int32),
        # accept_hist bins n_commit per verify call into 0..w+1 (w+2 bins).
        # INVARIANT: bin 0 is structurally zero and hist.sum() == calls —
        # every step path commits >= 1 token per call (accept() returns
        # n_commit = n_win + 1, the greedy body books its single token into
        # bin 1, and eos/budget clamps only shrink n_commit of an ACTIVE
        # row to >= 1).  Bin 0 is kept so the index IS the n_commit value
        # (aggregators like benchmarks' _add_hist sum bins positionally),
        # and as a canary: a nonzero bin 0 means a zero-commit call
        # slipped through.  Rejection sampling makes n_commit == 1 (bonus
        # only) the common case — bin 1, never bin 0.
        "accept_hist": jnp.zeros((B, spec.w + 2), jnp.int32),
        "rank_hist": jnp.zeros((B, max(ranks, 1)), jnp.int32),
        "alloc_ctx": jnp.zeros((B, spec.k + 1), jnp.int32),     # n_ctx per call
        "accepted_ctx": jnp.zeros((B,), jnp.int32),             # drafted tokens
        "accepted_bigram": jnp.zeros((B,), jnp.int32),          # accepted per src
    }
    if spec.arms is not None:
        # per-slot bandit state rides in the stats dict: donated with the
        # DecodeState and zeroed by the same slot-reset sweep as the
        # call/token counters (admission AND release)
        st.update(init_arm_stats(B, len(spec.arms)))
    return st


def _draft_adaptive(spec: SpecConfig, tables: Optional[NGramTables],
                    buf, buf_len, last, arm):
    """Arm-masked drafting: (k_max, w_max) candidates for every slot.

    One genuine draft per distinct positive arm depth (the context sweep's
    hash is a function of w — see drafters.multi_depth_draft), selected per
    slot by its chosen arm.  An all-greedy arm table drafts nothing.
    """
    B = buf.shape[0]
    sw = dispatch.unique_sweep_widths(spec.arms)
    if not sw:                              # every arm is (k, 0): greedy
        return (jnp.zeros((B, spec.k, spec.w), jnp.int32),
                jnp.zeros((B, spec.k), bool),
                jnp.zeros((B,), jnp.int32))
    widx = jnp.asarray([sw.index(w) if w > 0 else 0
                        for _, w in spec.arms], jnp.int32)[arm]
    draft_fn = lambda w: _draft(
        dataclasses.replace(spec, w=w, arms=None), tables, buf, buf_len,
        last)
    return multi_depth_draft(draft_fn, sw, spec.w, widx)


# ---------------------------------------------------------------------------
# state construction / slot admission
# ---------------------------------------------------------------------------
def _sampling_leaves(B: int) -> Dict[str, jnp.ndarray]:
    """Greedy-default per-slot sampling leaves (the admit/release reset)."""
    return dict(rng_key=jnp.zeros((B, 2), jnp.uint32),
                temperature=jnp.zeros((B,), jnp.float32),
                top_p=jnp.ones((B,), jnp.float32))


def empty_decode_state(cfg: ModelConfig, spec: SpecConfig, num_slots: int,
                       buf_size: int,
                       paged: Optional[PagedConfig] = None) -> DecodeState:
    """All-slots-free state for a continuous-batching engine.

    With ``paged``, the model cache is a shared page pool + per-slot page
    tables instead of per-slot linear buffers; ``buf_size`` (the token
    buffer / logical KV capacity per slot) is rounded up to whole pages.
    """
    spec.validate_arms()
    spec.validate_tree()
    B = num_slots
    if paged is not None:
        ps = paged.resolve_page_size(cfg)
        buf_size = -(-buf_size // ps) * ps
        pps = buf_size // ps
        model = C.init_paged_state(cfg, B, paged.num_pages or B * pps,
                                   ps, pps)
    else:
        model = M.init_state(cfg, B, buf_size)
    return DecodeState(
        buf=jnp.zeros((B, buf_size), jnp.int32),
        buf_len=jnp.zeros((B,), jnp.int32),
        prompt_len=jnp.zeros((B,), jnp.int32),
        budget=jnp.zeros((B,), jnp.int32),
        eos_id=jnp.full((B,), -1, jnp.int32),
        done=jnp.ones((B,), bool),
        active=jnp.zeros((B,), bool),
        model=model,
        stats=_init_stats(spec, B),
        **_sampling_leaves(B))


def init_decode_state(params, cfg: ModelConfig, spec: SpecConfig,
                      prompt: jnp.ndarray,
                      max_new_tokens: Optional[jnp.ndarray] = None,
                      eos_id: Optional[jnp.ndarray] = None,
                      buf_size: Optional[int] = None,
                      paged: Optional[PagedConfig] = None,
                      temperature: Optional[jnp.ndarray] = None,
                      top_p: Optional[jnp.ndarray] = None,
                      rng: Optional[jnp.ndarray] = None) -> DecodeState:
    """Prefill every row of ``prompt`` (B, P) into a fresh DecodeState.

    The static buffer is sized by spec.max_new_tokens (grown to cover
    concrete per-row ``max_new_tokens``; traced budgets must not exceed
    spec.max_new_tokens) unless ``buf_size`` is given.

    ``paged`` switches the KV layout to the shared page pool: each row gets
    ceil(P / page_size) pages up front and grows on the fly inside
    spec_step.  The default pool covers the worst case, so one-shot
    ``generate`` can never exhaust it — pool pressure is a serving concern
    (ServingEngine's page-reservation admission).

    Sampling (requires ``spec.sampling`` — a silent greedy fallback would be
    a correctness trap): ``temperature``/``top_p`` broadcast to per-row f32
    controls, ``rng`` is either one base key (2,) — expanded per row via
    fold_in(row) — or explicit per-row keys (B, 2).  The prompt's first free
    token is already a sampling event: it draws from the row key's first
    split, and the carry half seeds the step loop.
    """
    spec.validate_arms()
    spec.validate_tree()
    if not spec.sampling and (temperature is not None or top_p is not None
                              or rng is not None):
        raise ValueError(
            "temperature/top_p/rng need SpecConfig(sampling=True): the "
            "sampled verification walk is compiled statically "
            "(DESIGN.md §12); without it these knobs would silently "
            "degrade to greedy")
    B, P = prompt.shape
    budget = (jnp.full((B,), spec.max_new_tokens, jnp.int32)
              if max_new_tokens is None
              else jnp.broadcast_to(jnp.asarray(max_new_tokens, jnp.int32),
                                    (B,)))
    cap = spec.max_new_tokens
    if max_new_tokens is not None:
        try:
            cap = max(cap, int(jnp.max(budget)))
        except (jax.errors.ConcretizationTypeError,
                jax.errors.TracerArrayConversionError):
            pass  # traced budgets: caller promises <= spec.max_new_tokens
    L = buf_size or P + cap + spec.w + 2
    if (buf_size is None and dispatch.use_pallas(cfg.backend)
            and dispatch.pallas_verify_supported(cfg)):
        # size the cache so the verify kernel streams whole blocks and
        # never repads per call (padded slots are masked by cur_len, so
        # the extra length cannot change outputs)
        L = dispatch.align_cache_len(L, cfg.kernel_block_s)
    eos = (jnp.full((B,), spec.eos_id, jnp.int32) if eos_id is None
           else jnp.broadcast_to(jnp.asarray(eos_id, jnp.int32), (B,)))
    if paged is not None:
        ps = paged.resolve_page_size(cfg)
        L = -(-L // ps) * ps
        pps = L // ps
        model = C.init_paged_state(cfg, B, paged.num_pages or B * pps,
                                   ps, pps)
        model = C.grow_pages(model, jnp.full((B,), P, jnp.int32),
                             jnp.ones((B,), bool))
    else:
        model = M.init_state(cfg, B, L)
    buf = jnp.zeros((B, L), jnp.int32)
    buf = jax.lax.dynamic_update_slice(buf, prompt.astype(jnp.int32), (0, 0))

    logits_p, model = M.prefill(params, cfg, model, tokens=prompt)
    leaves = _sampling_leaves(B)
    if spec.sampling:
        if temperature is not None:
            leaves["temperature"] = jnp.broadcast_to(
                jnp.asarray(temperature, jnp.float32), (B,))
        if top_p is not None:
            leaves["top_p"] = jnp.broadcast_to(
                jnp.asarray(top_p, jnp.float32), (B,))
        if rng is not None:
            keys = per_row_keys(rng, B)
        else:
            keys = leaves["rng_key"]
        nk = jax.vmap(jax.random.split)(keys)               # (B, 2, 2)
        first = sample_token(logits_p[:, -1], nk[:, 0],
                             leaves["temperature"], leaves["top_p"])
        leaves["rng_key"] = nk[:, 1]
    else:
        first = jnp.argmax(logits_p[:, -1], axis=-1).astype(jnp.int32)
    buf = buf.at[:, P].set(first)
    stats = _init_stats(spec, B)
    stats["tokens"] = stats["tokens"] + 1
    return DecodeState(
        buf=buf,
        buf_len=jnp.full((B,), P + 1, jnp.int32),
        prompt_len=jnp.full((B,), P, jnp.int32),
        budget=budget,
        eos_id=eos,
        done=(first == eos) & (eos >= 0),
        active=jnp.ones((B,), bool),
        model=model,
        stats=stats,
        **leaves)


def _admit_body(params, cfg: ModelConfig, state: DecodeState,
                slot: jnp.ndarray, prompt: jnp.ndarray,
                max_new_tokens: jnp.ndarray, eos_id: jnp.ndarray,
                temperature: jnp.ndarray = 0.0, top_p: jnp.ndarray = 1.0,
                rng_key: Optional[jnp.ndarray] = None) -> DecodeState:
    """Un-jitted body of ``admit_slot`` (re-jitted with explicit
    NamedShardings by ``make_sharded_slot_fns`` for mesh serving)."""
    P = prompt.shape[0]
    L = state.buf_size
    paged = C.is_paged(state.model)
    row_model = M.init_state(cfg, 1, P if paged else L)
    logits, row_model = M.prefill(params, cfg, row_model,
                                  tokens=prompt[None].astype(jnp.int32),
                                  last_only=True)
    temp = jnp.asarray(temperature, jnp.float32)
    topp = jnp.asarray(top_p, jnp.float32)
    key = (jnp.zeros((2,), jnp.uint32) if rng_key is None
           else jnp.asarray(rng_key, jnp.uint32))
    # the request's first free token is its first sampling event: draw it
    # from the admitted key's first split, carry the second into the slot
    k_use, k_carry = jax.random.split(key)
    first = sample_token(logits[:1, -1], k_use[None], temp[None],
                         topp[None])[0]
    row = jnp.zeros((L,), jnp.int32)
    row = jax.lax.dynamic_update_slice(row, prompt.astype(jnp.int32), (0,))
    row = row.at[P].set(first)
    # zero every per-slot stats row — including the adaptive bandit's
    # per-arm pulls/rewards, so a reused slot starts exploring afresh
    stats = C.zero_slot_stats(state.stats, slot)
    stats["tokens"] = stats["tokens"].at[slot].set(1)
    if paged:
        ps = C.paged_dims(state.model)[1]
        model = C.free_slot_pages(state.model, slot)
        model = C.alloc_slot_pages(model, slot, C.pages_for_len(P, ps))
        model = C.insert_slot_paged(model, row_model, slot, P)
    else:
        model = C.insert_slot(state.model, row_model, slot)
    return DecodeState(
        buf=state.buf.at[slot].set(row),
        buf_len=state.buf_len.at[slot].set(P + 1),
        prompt_len=state.prompt_len.at[slot].set(P),
        budget=state.budget.at[slot].set(max_new_tokens),
        eos_id=state.eos_id.at[slot].set(eos_id),
        done=state.done.at[slot].set((first == eos_id) & (eos_id >= 0)),
        active=state.active.at[slot].set(True),
        model=model,
        stats=stats,
        rng_key=state.rng_key.at[slot].set(k_carry),
        temperature=state.temperature.at[slot].set(temp),
        top_p=state.top_p.at[slot].set(topp))


@functools.partial(jax.jit, static_argnums=(1,), donate_argnums=(2,))
def admit_slot(params, cfg: ModelConfig, state: DecodeState,
               slot: jnp.ndarray, prompt: jnp.ndarray,
               max_new_tokens: jnp.ndarray, eos_id: jnp.ndarray,
               temperature: jnp.ndarray = 0.0, top_p: jnp.ndarray = 1.0,
               rng_key: Optional[jnp.ndarray] = None) -> DecodeState:
    """Prefill ``prompt`` (P,) into slot ``slot`` of a shared DecodeState.

    The freed slot's model cache is fully overwritten (cache.insert_slot), so
    nothing can leak from the slot's previous occupant.  Compiles once per
    prompt length P — the scheduler's length bucketing keeps that bounded.
    ``slot``/``max_new_tokens``/``eos_id`` (and the per-request sampling
    controls ``temperature``/``top_p``/``rng_key``) are traced, so
    heterogeneous requests reuse the same executable.  The defaults admit a
    greedy request; the prompt's first free token is sampled from the
    admitted key (temperature 0 reduces to the argmax bit-exactly).

    Paged states prefill the row into a P-sized scratch linear cache, then
    allocate ceil(P / page_size) pool pages for the slot and scatter the
    prefix KV through its fresh page table (spec_step grows further pages on
    the fly).  A defensive free first makes admission safe even if release
    was skipped — free_slot_pages is idempotent.
    """
    return _admit_body(params, cfg, state, slot, prompt, max_new_tokens,
                       eos_id, temperature, top_p, rng_key)


def _release_body(state: DecodeState, slot: jnp.ndarray) -> DecodeState:
    """Un-jitted body of ``release_slot`` (see ``make_sharded_slot_fns``)."""
    model = state.model
    if C.is_paged(model):
        model = C.free_slot_pages(model, slot)
    return dataclasses.replace(
        state,
        model=model,
        stats=C.zero_slot_stats(state.stats, slot),
        active=state.active.at[slot].set(False),
        done=state.done.at[slot].set(True),
        rng_key=state.rng_key.at[slot].set(jnp.zeros((2,), jnp.uint32)),
        temperature=state.temperature.at[slot].set(0.0),
        top_p=state.top_p.at[slot].set(1.0))


@functools.partial(jax.jit, donate_argnums=(0,))
def release_slot(state: DecodeState, slot: jnp.ndarray) -> DecodeState:
    """Mark a retired row's slot as free.  Linear caches are overwritten on
    the next admit (see cache.reset_slot for eager scrubbing); paged caches
    return the slot's pages to the free stack NOW — reclaiming pool capacity
    at retirement is the whole point of the paged layout.  The slot's stats
    rows (including the adaptive bandit's per-arm state) are zeroed eagerly:
    callers must read a retiring slot's stats BEFORE releasing it, and a
    freed slot must not keep steering arm choices it can no longer use."""
    return _release_body(state, slot)


def make_sharded_slot_fns(cfg: ModelConfig, spec: SpecConfig, *,
                          params_sh, state_sh, tables_sh, scalar_sh):
    """jitted (spec_step, admit_slot, release_slot) with every input AND
    output pinned to explicit NamedShardings — the mesh-serving versions of
    the module-level jits (DESIGN.md §10).

    Pinning out_shardings == in_shardings per state leaf is what keeps the
    two serving guarantees alive under a mesh: (a) buffer DONATION stays
    legal (XLA only aliases a donated buffer into an output with the same
    sharding), so the sharded KV cache still updates in place; (b) the
    state's placement is a fixed point of every function here, so the
    serving loop's step N+1 sees bit-identical arg shardings to step N and
    the step compiles exactly ONCE per shape — the same single-trace
    contract the unsharded path has.  Scalars (slot ids, prompts, budgets)
    are replicated.
    """
    step = jax.jit(
        lambda params, state, tables: _step_body(params, cfg, spec, tables,
                                                 state),
        in_shardings=(params_sh, state_sh, tables_sh),
        out_shardings=state_sh, donate_argnums=(1,))
    admit = jax.jit(
        lambda params, state, slot, prompt, mnt, eos, temp, topp, key:
        _admit_body(params, cfg, state, slot, prompt, mnt, eos, temp, topp,
                    key),
        in_shardings=(params_sh, state_sh, scalar_sh, scalar_sh, scalar_sh,
                      scalar_sh, scalar_sh, scalar_sh, scalar_sh),
        out_shardings=state_sh, donate_argnums=(1,))
    release = jax.jit(
        lambda state, slot: _release_body(state, slot),
        in_shardings=(state_sh, scalar_sh),
        out_shardings=state_sh, donate_argnums=(0,))
    return step, admit, release


# ---------------------------------------------------------------------------
# the step
# ---------------------------------------------------------------------------
def _spec_body(params, cfg: ModelConfig, spec: SpecConfig,
               tables: Optional[NGramTables], s: DecodeState) -> DecodeState:
    B, L = s.buf.shape
    adaptive = spec.arms is not None
    if adaptive:
        spec.validate_arms()
    topo = None
    if spec.tree:
        spec.validate_tree()
        if M.has_recurrent(cfg):
            raise ValueError(
                "tree speculation needs an attention-only arch: recurrent "
                "mixers verify rows as causal sequences, which has no "
                "valid tree layout (DESIGN.md §11)")
        if tables is None:
            raise ValueError("tree speculation needs NGramTables "
                             "(off-spine branches come from bigram_topk)")
        topo = T.topology(spec.k, spec.w, spec.tree_branch)
    if C.is_paged(s.model):
        # on-the-fly page growth: this step commits at most w+1 tokens per
        # row (positions cur_len .. cur_len+w), so cover cur_len + w + 1
        # before the verify/commit touches the pool (w is the compile-time
        # maximum under adaptive arms: growth is sized for the worst arm)
        act = s.active & (~s.done) & (s.buf_len - s.prompt_len < s.budget)
        s = dataclasses.replace(
            s, model=C.grow_pages(s.model,
                                  s.model["cur_len"] + spec.w + 1, act))
    buf_c, len_c, done_c, state_c = s.buf, s.buf_len, s.done, s.model
    st = s.stats
    if spec.sampling:
        # one split per slot per step, inside the jit: half drives this
        # step's per-level gumbel draws, half is carried (donated in place)
        nk = jax.vmap(jax.random.split)(s.rng_key)          # (B, 2, 2)
        use_keys, carry_keys = nk[:, 0], nk[:, 1]
    else:
        use_keys, carry_keys = None, s.rng_key
    last = jnp.take_along_axis(buf_c, (len_c - 1)[:, None], axis=1)[:, 0]
    if adaptive:
        # per-slot, per-step arm selection INSIDE the jit: UCB over the
        # slot's own (B, A) stats, then mask the fixed (k_max, w_max)
        # shapes down to the chosen arm — no recompile can ever occur
        slow = (tree_arm_slowdowns(cfg, spec.arms, spec.tree_branch,
                                   spec.adapt_ell) if spec.tree
                else arm_slowdowns(cfg, spec.arms, spec.adapt_ell))
        arm = choose_arms(st, slow, spec.adapt_explore)         # (B,)
        k_eff = jnp.asarray([a[0] for a in spec.arms], jnp.int32)[arm]
        w_eff = jnp.asarray([a[1] for a in spec.arms], jnp.int32)[arm]
        drafts, valid, n_ctx = _draft_adaptive(spec, tables, buf_c, len_c,
                                               last, arm)
    else:
        arm = k_eff = w_eff = None
        drafts, valid, n_ctx = _draft(spec, tables, buf_c, len_c, last)
    if spec.tree:
        # ONE (B, 1, N+1) verify call scores the whole token tree; the
        # topology's ancestor mask + per-level positions make every
        # root-to-leaf path bit-identical to a linear row of its tokens
        nodes = T.fill_tree(topo, drafts, tables,
                            buf=buf_c, buf_len=len_c)           # (B, N)
        rows = jnp.concatenate([last[:, None], nodes],
                               axis=1)[:, None, :]              # (B,1,N+1)
        logits, tails = M.verify(params, cfg, state_c, rows,
                                 pos_off=topo.pos_off,
                                 tail_mask=topo.anc_mask)
        if spec.sampling:
            # noise keyed per tree LEVEL (pos_off), so same-level nodes
            # share it: alive nodes share prefixes -> logits -> samples,
            # and the slot's sampled trajectory is well defined across the
            # whole tree (duplicate-token siblings included)
            preds_n = sample_predictions(logits, use_keys, s.temperature,
                                         s.top_p, levels=topo.pos_off)[:, 0]
        else:
            preds_n = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        # path views: (B, P, w) draft tokens / (B, P, w+1) predictions
        drafts_pv = jnp.take(nodes, topo.path_nodes, axis=1)
        greedy_pv = jnp.take(preds_n, topo.path_inputs, axis=1)
        row_mask = None
        if adaptive:
            # a (width_b, depth_b) arm keeps exactly the paths whose branch
            # indices all fall below width_b (NOT a prefix of the path
            # list — eligibility is scattered through lex order)
            row_mask = (jnp.asarray(topo.path_max_branch, jnp.int32)[None]
                        < k_eff[:, None])
        acc = accept(drafts_pv, greedy_pv, w_eff=w_eff, row_mask=row_mask)
    else:
        rows = jnp.concatenate(
            [jnp.broadcast_to(last[:, None, None], (B, spec.k, 1)), drafts],
            axis=-1)                                            # (B,k,w+1)
        logits, tails = M.verify(params, cfg, state_c, rows)
        if spec.sampling:
            # noise keyed per position level and SHARED across the k rows:
            # rows alive at level j have identical prefixes -> identical
            # logits -> identical samples, so acceptance walks one sampled
            # trajectory and the bonus is its first divergent (= residual)
            # token — the point-mass rejection rule, lossless for any k
            greedy = sample_predictions(logits, use_keys, s.temperature,
                                        s.top_p)
        else:
            greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        acc = accept(drafts, greedy, k_eff=k_eff, w_eff=w_eff)
    active = s.active & (~done_c) & (len_c - s.prompt_len < s.budget)
    budget = jnp.maximum(s.prompt_len + s.budget - len_c, 0)
    n_commit = jnp.where(active, jnp.minimum(acc.n_commit, budget), 0)
    # eos truncation: commit only up to (and including) the first eos
    iseos = (acc.tokens == s.eos_id[:, None]) & (s.eos_id >= 0)[:, None]
    first_eos = jnp.argmax(iseos, axis=1)
    has_eos = iseos.any(axis=1) & (first_eos < n_commit)
    n_commit = jnp.where(has_eos, first_eos + 1, n_commit)
    done_c = done_c | (has_eos & active)
    # commit the model state
    if spec.tree:
        # gather the winning PATH's verify inputs out of the (N+1)-wide
        # tree tails -> a (w+1)-wide linear tail, then the stock commit
        # (winner row 0 of 1) writes it — linear AND paged paths unchanged
        sel = jnp.asarray(topo.path_inputs, jnp.int32)[acc.winner]  # (B,w+1)
        idx = sel[None, :, None, :, None, None]
        tails = {g: {kk: jnp.take_along_axis(tt, idx, axis=3)
                     for kk, tt in d.items()} for g, d in tails.items()}
        state_n = M.commit_kv_tails(cfg, state_c, tails,
                                    jnp.zeros((B,), jnp.int32), n_commit)
    elif not M.has_recurrent(cfg):
        state_n = M.commit_kv_tails(cfg, state_c, tails, acc.winner,
                                    n_commit)
    else:
        row_tok = jnp.take_along_axis(
            rows, acc.winner[:, None, None], axis=1)[:, 0]      # (B,w+1)
        _, state_n = M.decode(params, cfg, state_c, row_tok,
                              n_commit=n_commit)
    # write accepted tokens into the buffer
    pos = jnp.arange(spec.w + 1)[None, :]
    slots = jnp.clip(len_c[:, None] + pos, 0, L - 1)
    gate = pos < n_commit[:, None]
    b_idx = jnp.broadcast_to(jnp.arange(B)[:, None], slots.shape)
    old = buf_c[b_idx, slots]
    buf_n = buf_c.at[b_idx, slots].set(
        jnp.where(gate, acc.tokens, old))
    len_n = len_c + n_commit
    done_n = done_c | (len_n - s.prompt_len >= s.budget)
    # ---- stats ----
    st = dict(st)
    st["calls"] = st["calls"] + active.astype(jnp.int32)
    st["tokens"] = st["tokens"] + n_commit
    st["accept_hist"] = st["accept_hist"].at[
        jnp.arange(B), jnp.clip(n_commit, 0, spec.w + 1)].add(
            active.astype(jnp.int32))
    n_win = jnp.take_along_axis(acc.n_acc, acc.winner[:, None], 1)[:, 0]
    st["rank_hist"] = st["rank_hist"].at[jnp.arange(B), acc.winner].add(
        (active & (n_win > 0)).astype(jnp.int32))
    st["alloc_ctx"] = st["alloc_ctx"].at[
        jnp.arange(B), jnp.clip(n_ctx, 0, spec.k)].add(
            active.astype(jnp.int32))
    # winning path's origin: the drafter row its first branch tracks (tree)
    # or the winning row itself (linear)
    from_ctx = (jnp.asarray(topo.path_first, jnp.int32)[acc.winner] < n_ctx
                if spec.tree else acc.winner < n_ctx)
    acc_drafted = jnp.maximum(n_commit - 1, 0)
    st["accepted_ctx"] = st["accepted_ctx"] + jnp.where(
        active & from_ctx, acc_drafted, 0)
    st["accepted_bigram"] = st["accepted_bigram"] + jnp.where(
        active & ~from_ctx, acc_drafted, 0)
    if adaptive:
        # reward the pulled arm with the tokens its call committed (bonus
        # included — the same tokens-per-call quantity AdaptiveKW tracks)
        st = update_arm_stats(st, arm, n_commit, active, spec.adapt_ema)
    return dataclasses.replace(s, buf=buf_n, buf_len=len_n, done=done_n,
                               model=state_n, stats=st,
                               rng_key=carry_keys)


def _greedy_body(params, cfg: ModelConfig, spec: SpecConfig,
                 tables: Optional[NGramTables], s: DecodeState) -> DecodeState:
    B, L = s.buf.shape
    if C.is_paged(s.model):
        act = s.active & (~s.done) & (s.buf_len - s.prompt_len < s.budget)
        s = dataclasses.replace(
            s, model=C.grow_pages(s.model, s.model["cur_len"] + 1, act))
    buf_c, len_c, done_c, state_c = s.buf, s.buf_len, s.done, s.model
    last = jnp.take_along_axis(buf_c, (len_c - 1)[:, None], axis=1)
    logits, state_n = M.decode(params, cfg, state_c, last)
    active = s.active & (~done_c) & (len_c - s.prompt_len < s.budget)
    # decode advances cur_len by 1 for every row; freeze inactive rows so
    # the cur_len == buf_len - 1 invariant holds for done/free slots too
    # (their discarded cache/state writes are row-local and invisible:
    # key_positions only exposes p < cur_len, and admission overwrites).
    state_n = {**state_n,
               "cur_len": state_c["cur_len"] + active.astype(jnp.int32)}
    if spec.sampling:
        nk = jax.vmap(jax.random.split)(s.rng_key)          # (B, 2, 2)
        nxt = sample_token(logits[:, -1], nk[:, 0], s.temperature, s.top_p)
        carry_keys = nk[:, 1]
    else:
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        carry_keys = s.rng_key
    slots = jnp.clip(len_c, 0, L - 1)
    buf_n = buf_c.at[jnp.arange(B), slots].set(
        jnp.where(active, nxt, buf_c[jnp.arange(B), slots]))
    len_n = len_c + active.astype(jnp.int32)
    done_n = done_c | (len_n - s.prompt_len >= s.budget)
    done_n = done_n | ((nxt == s.eos_id) & (s.eos_id >= 0))
    st = dict(s.stats)
    st["calls"] = st["calls"] + active.astype(jnp.int32)
    st["tokens"] = st["tokens"] + active.astype(jnp.int32)
    # a greedy-body call commits exactly one token, so it lands in bin 1 of
    # the shared n_commit histogram — keeping "hist.sum() == calls" true for
    # every strategy, and bin 0 structurally zero engine-wide (see
    # _init_stats: every step path commits >= 1 token per call)
    st["accept_hist"] = st["accept_hist"].at[:, 1].add(
        active.astype(jnp.int32))
    return dataclasses.replace(s, buf=buf_n, buf_len=len_n, done=done_n,
                               model=state_n, stats=st,
                               rng_key=carry_keys)


def _step_body(params, cfg: ModelConfig, spec: SpecConfig,
               tables: Optional[NGramTables], state: DecodeState
               ) -> DecodeState:
    body = _greedy_body if spec.strategy == "greedy" else _spec_body
    return body(params, cfg, spec, tables, state)


@functools.partial(jax.jit, static_argnums=(1, 2), donate_argnums=(3,))
def spec_step(params, cfg: ModelConfig, spec: SpecConfig, state: DecodeState,
              tables: Optional[NGramTables] = None) -> DecodeState:
    """One jitted draft→verify→commit iteration over every active slot.

    Reusable across calls: shapes are those of ``state``, so a serving loop
    compiles this exactly once per (cfg, spec, state-shape) and then admits /
    retires requests between invocations.  Rows that are inactive or done
    commit nothing and their stats are untouched.

    The incoming ``state`` is DONATED (as in admit_slot/release_slot): the
    serving loop always rebinds, and donation lets XLA update the KV cache
    in place instead of copying every leaf per verify call.  Callers that
    need the previous state must copy it first.
    """
    return _step_body(params, cfg, spec, tables, state)


# ---------------------------------------------------------------------------
# one-shot generation (a while_loop over the same step body)
# ---------------------------------------------------------------------------
def generate(params, cfg: ModelConfig, spec: SpecConfig,
             prompt: jnp.ndarray, tables: Optional[NGramTables] = None,
             eos_id: Optional[jnp.ndarray] = None,
             paged: Optional[PagedConfig] = None,
             temperature: Optional[jnp.ndarray] = None,
             top_p: Optional[jnp.ndarray] = None,
             rng: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Generate up to max_new_tokens for every row of ``prompt`` (B, P).

    ``eos_id``: optional per-row override of spec.eos_id (traced, so
    heterogeneous batches share one compilation).  ``paged`` runs the same
    loop over the paged KV layout (bit-identical outputs — the parity
    tests' contract).  ``temperature``/``top_p``/``rng`` (scalar or
    per-row; requires ``spec.sampling``) run the lossless sampled
    verification walk instead of greedy — see ``init_decode_state``.
    Returns (buf (B, L), buf_len (B,), stats).  jit-compatible end to end.
    """
    state = init_decode_state(params, cfg, spec, prompt, eos_id=eos_id,
                              paged=paged, temperature=temperature,
                              top_p=top_p, rng=rng)

    def cond(s: DecodeState):
        return (~s.done).any() & ((s.buf_len - s.prompt_len) < s.budget).any()

    def body(s: DecodeState):
        return _step_body(params, cfg, spec, tables, s)

    state = jax.lax.while_loop(cond, body, state)
    return state.buf, state.buf_len, state.stats


def greedy_reference(params, cfg: ModelConfig, prompt: jnp.ndarray,
                     max_new_tokens: int) -> jnp.ndarray:
    """Plain greedy decoding via full forward() only — the test oracle.

    Uses a FIXED-shape buffer (causality guarantees the garbage tail can't
    influence the position being read), so the whole loop compiles once.
    """
    B, P = prompt.shape
    L = P + max_new_tokens
    buf = jnp.zeros((B, L), jnp.int32)
    buf = jax.lax.dynamic_update_slice(buf, prompt.astype(jnp.int32), (0, 0))

    @jax.jit
    def step(buf, cur):
        logits, _ = M.forward(params, cfg, tokens=buf)
        nxt = jnp.take_along_axis(
            jnp.argmax(logits, axis=-1).astype(jnp.int32),
            (cur - 1)[None].repeat(B, 0)[:, None], axis=1)[:, 0]
        return buf.at[:, cur].set(nxt)

    for i in range(max_new_tokens):
        buf = step(buf, jnp.asarray(P + i))
    return buf


def sampling_reference(params, cfg: ModelConfig, prompt: jnp.ndarray,
                       max_new_tokens: int, rng: jnp.ndarray,
                       temperature, top_p=1.0) -> jnp.ndarray:
    """Plain temperature/top-p decoding via full forward() only — the
    sampled sibling of ``greedy_reference`` and the distributional-parity
    oracle.

    Per-row key chains mirror the engine's exactly (``per_row_keys`` then
    one split per sampled token, first token included), and every draw goes
    through the SAME primitive the spec path uses
    (core/verify.py::sample_token on shape_logits-shaped distributions) —
    so spec-vs-plain parity isolates the acceptance walk, not sampler
    differences.  No eos/budget logic: fixed max_new_tokens per row.
    """
    B, P = prompt.shape
    L = P + max_new_tokens
    buf = jnp.zeros((B, L), jnp.int32)
    buf = jax.lax.dynamic_update_slice(buf, prompt.astype(jnp.int32), (0, 0))
    temp = jnp.broadcast_to(jnp.asarray(temperature, jnp.float32), (B,))
    topp = jnp.broadcast_to(jnp.asarray(top_p, jnp.float32), (B,))
    keys = per_row_keys(jnp.asarray(rng, jnp.uint32), B)

    @jax.jit
    def step(buf, keys, cur):
        logits, _ = M.forward(params, cfg, tokens=buf)
        row_logits = logits[:, cur - 1]                       # (B, V)
        nk = jax.vmap(jax.random.split)(keys)
        nxt = sample_token(row_logits, nk[:, 0], temp, topp)
        return buf.at[:, cur].set(nxt), nk[:, 1]

    for i in range(max_new_tokens):
        buf, keys = step(buf, keys, jnp.asarray(P + i))
    return buf
