"""Representative production configs the jaxpr-level analyzers trace.

The engine's correctness contracts (one ``spec_step`` trace, donation
soundness, full sharding coverage) are claims about the REAL entry points
under every serving mode, so the analyzers trace the real functions
(``_step_body``/``_admit_body``/``_release_body`` — exactly what
``spec_step``/``admit_slot``/``release_slot`` and ``generate``'s while-body
jit) on abstract ``DecodeState`` inputs built from this registry:

    linear/paged x greedy/mixed x sampled x tree x adaptive arms

on a deliberately tiny 2-layer model (the contracts are structural — they
do not depend on model size, and a tiny model keeps ``repro-lint`` a
seconds-scale CI gate).  The mesh axis of the matrix is covered by
resolving every case's state against the registry's mesh shapes with
``decode_state_pspec(strict=True)`` (jaxpr_rules.check_sharding_coverage);
*multi-device* trace checks need real devices and stay in
tests/test_sharded_serving.py's compile-count spies.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.ngram_tables import NGramTables
from ..core.spec_engine import (DecodeState, PagedConfig, SpecConfig,
                                empty_decode_state)
from ..models import model as M
from ..models.config import ModelConfig

NUM_SLOTS = 4          # divisible by every registry mesh's batch chain
PROMPT_LEN = 8
MAX_NEW = 8


@dataclasses.dataclass(frozen=True)
class MeshShape:
    """Stand-in for jax.sharding.Mesh in PURE SPEC RESOLUTION: the
    decode_state_pspec/resolve_axis rules only consult ``mesh.shape``, so
    coverage checks need no physical devices (CI runs on one CPU)."""
    name: str
    shape: Dict[str, int]


# the mesh/1-device axis of the registry matrix
MESHES: Tuple[MeshShape, ...] = (
    MeshShape("1dev", {"data": 1, "model": 1}),
    MeshShape("2x2", {"data": 2, "model": 2}),
    MeshShape("pod3d", {"pod": 2, "data": 2, "model": 2}),
)


@dataclasses.dataclass(frozen=True)
class Case:
    name: str
    spec: SpecConfig
    paged: Optional[PagedConfig] = None

    @property
    def needs_tables(self) -> bool:
        return self.spec.strategy != "greedy"


def _spec(**kw) -> SpecConfig:
    base = dict(k=4, w=3, q=1, strategy="mixed", max_new_tokens=MAX_NEW)
    base.update(kw)
    return SpecConfig(**base)


CASES: Tuple[Case, ...] = (
    Case("linear-greedy", _spec(strategy="greedy")),
    Case("linear-mixed", _spec()),
    Case("linear-sampled", _spec(sampling=True)),
    Case("linear-adaptive", _spec(arms=((1, 0), (2, 2), (4, 3)))),
    Case("tree", _spec(w=2, tree=True, tree_branch=2)),
    Case("paged-mixed", _spec(), paged=PagedConfig(num_pages=0, page_size=8)),
)


@functools.lru_cache(maxsize=None)
def tiny_config() -> ModelConfig:
    # dims chosen divisible by every registry mesh axis chain (heads 4,
    # kv 2, ffn 128, slots 4) so sharding coverage sees zero legitimate
    # replication fallbacks — any ShardingFallbackWarning is a finding
    return ModelConfig(name="lint-tiny", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=61,
                       param_dtype=jnp.float32,
                       compute_dtype=jnp.float32).validate()


@functools.lru_cache(maxsize=None)
def tiny_params():
    return M.init_params(jax.random.PRNGKey(0), tiny_config())


@functools.lru_cache(maxsize=None)
def tiny_tables() -> NGramTables:
    """Value-free stand-in tables: drafting only gathers from them, so
    zeros trace/lower identically to model-built tables."""
    cfg = tiny_config()
    k_max, w_max = 8, 8
    return NGramTables(
        unigram_topk=jnp.zeros((k_max,), jnp.int32),
        bigram_topk=jnp.zeros((cfg.vocab_size, k_max), jnp.int32),
        bigram_chain=jnp.zeros((cfg.vocab_size, w_max), jnp.int32))


def buf_size(spec: SpecConfig) -> int:
    # mirrors ServingEngine._init_continuous's sizing arithmetic
    return PROMPT_LEN + MAX_NEW + spec.w + 2


@dataclasses.dataclass
class BuiltCase:
    case: Case
    cfg: ModelConfig
    params: Dict
    tables: Optional[NGramTables]
    state: DecodeState            # concrete tiny state (cheap: no params)

    @property
    def name(self) -> str:
        return self.case.name

    @property
    def spec(self) -> SpecConfig:
        return self.case.spec

    @property
    def state_struct(self):
        return jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.state)

    def prompt_struct(self):
        return jax.ShapeDtypeStruct((PROMPT_LEN,), jnp.int32)


def build_case(case: Case) -> BuiltCase:
    cfg = tiny_config()
    state = empty_decode_state(cfg, case.spec, NUM_SLOTS,
                               buf_size(case.spec), paged=case.paged)
    return BuiltCase(case=case, cfg=cfg, params=tiny_params(),
                     tables=tiny_tables() if case.needs_tables else None,
                     state=state)


def built_cases() -> Tuple[BuiltCase, ...]:
    return tuple(build_case(c) for c in CASES)
