"""StableLM-2-1.6B: MHA (kv=32), 25% partial rotary, LayerNorm
[hf:stabilityai/stablelm-2-1_6b]."""
import jax.numpy as jnp
from ..models.config import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b", arch_type="dense",
        source="hf:stabilityai/stablelm-2-1_6b",
        num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=5632, vocab_size=100352,
        block_pattern=(BlockSpec("attn", "swiglu"),),
        norm="layernorm", rope="rope", partial_rotary_factor=0.25,
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke", arch_type="dense",
        source="hf:stabilityai/stablelm-2-1_6b",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512,
        block_pattern=(BlockSpec("attn", "swiglu"),),
        norm="layernorm", rope="rope", partial_rotary_factor=0.25,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    ).validate()
