"""Adaptive (k, w) controller — beyond-paper extension.

The paper sweeps a static (k, w) grid offline and notes (§5.2) that smarter
strategy allocation "could yield further gains".  This controller picks the
strategy ONLINE, per served batch, from a small set of precompiled arms:

    score(arm) = EMA_tokens_per_call(arm) / roofline_slowdown(arm | ell)

i.e. measured acceptance divided by the modeled call-time inflation
(core/phase.py), with a UCB exploration bonus.  Arms are a fixed list so the
jitted engine never recompiles outside the precompiled set (a TPU serving
requirement).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from ..models.config import ModelConfig
from .phase import slowdown


@dataclasses.dataclass
class ArmStats:
    tokens: float = 0.0
    calls: float = 0.0
    pulls: int = 0

    @property
    def tpc(self) -> float:
        return self.tokens / self.calls if self.calls else 1.0


DEFAULT_ARMS: Tuple[Tuple[int, int], ...] = ((1, 0), (5, 4), (10, 4),
                                             (10, 10), (25, 2))


class AdaptiveKW:
    def __init__(self, cfg: ModelConfig,
                 arms: Tuple[Tuple[int, int], ...] = DEFAULT_ARMS,
                 ell: int = 512, ema: float = 0.9,
                 explore: float = 0.3):
        self.cfg = cfg
        self.arms: List[Tuple[int, int]] = list(arms)
        self.ell = ell
        self.ema = ema
        self.explore = explore
        self.stats: Dict[Tuple[int, int], ArmStats] = {
            a: ArmStats() for a in self.arms}
        # modeled call slowdown per arm (the roofline prior)
        self.slow: Dict[Tuple[int, int], float] = {
            (k, w): slowdown(cfg, ell, k, w) if (k, w) != (1, 0) else 1.0
            for (k, w) in self.arms}
        self.total_pulls = 0

    def score(self, arm: Tuple[int, int]) -> float:
        s = self.stats[arm]
        # optimistic prior before any pull: assume half the draft accepted
        tpc = s.tpc if s.pulls else 1.0 + arm[1] * 0.5
        bonus = self.explore * math.sqrt(
            math.log(self.total_pulls + 1) / (s.pulls + 1e-9)) \
            if s.pulls else float("inf")
        return tpc / self.slow[arm] + bonus

    def choose(self) -> Tuple[int, int]:
        return max(self.arms, key=self.score)

    def update(self, arm: Tuple[int, int], tokens: float,
               calls: float) -> None:
        s = self.stats[arm]
        if s.pulls:
            s.tokens = self.ema * s.tokens + (1 - self.ema) * tokens
            s.calls = self.ema * s.calls + (1 - self.ema) * calls
        else:
            s.tokens, s.calls = tokens, calls
        s.pulls += 1
        self.total_pulls += 1

    def best_exploit(self) -> Tuple[int, int]:
        """Current best arm ignoring exploration bonus."""
        return max(self.arms,
                   key=lambda a: (self.stats[a].tpc if self.stats[a].pulls
                                  else 0.0) / self.slow[a])
