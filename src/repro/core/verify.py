"""Greedy acceptance logic for batched speculation (paper §4.1).

The verification model call already produced, for every draft row i, the
model's greedy next-token prediction after each of its w+1 input tokens
(``greedy[b, i, j]`` = argmax after consuming input j of row i, where input
0 is the last committed token and inputs 1..w are the draft).

Row i accepts n_i = length of the longest prefix of its draft matching the
model's own greedy predictions; the winner is the row with the largest n_i
(ties -> lowest row index, which under the mixed strategy prioritises the
context N-gram, matching the paper's ordering).  The winner always also
emits one *bonus* token (the model's prediction after its last accepted
token), so every call commits n* + 1 >= 1 tokens and the output equals plain
greedy decoding token-for-token.

Per-slot arm masking (DESIGN.md §9, §11): ``masked_acceptance`` restricts
slot b to its arm's sub-problem inside the shared compile-time shapes.  The
"rows" here are linear draft rows in linear mode and root-to-leaf PATHS of
the draft tree in tree mode — the tree path-walk reuses this helper with a
``row_mask`` of path eligibility instead of the prefix mask ``k_eff``
induces.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Acceptance(NamedTuple):
    tokens: jnp.ndarray    # (B, w+1) committed tokens (padded past n_commit)
    n_commit: jnp.ndarray  # (B,) = n* + 1
    winner: jnp.ndarray    # (B,) winning row index
    n_acc: jnp.ndarray     # (B, k) per-row accepted-draft lengths (stats)


def masked_acceptance(eq: jnp.ndarray,
                      k_eff: Optional[jnp.ndarray] = None,
                      w_eff: Optional[jnp.ndarray] = None,
                      row_mask: Optional[jnp.ndarray] = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Arm-mask a per-token match matrix down to per-row ranking scores.

    eq: (B, k, w) bool — token j of row i matched the model's greedy
    prediction.  Returns ``(n_acc, n_rank)``, both (B, k) int32:

      - ``n_acc[b, i]``  = longest matching prefix of row i, truncated at
        slot b's depth ``w_eff[b]`` when given (depth masking: a masked step
        may carry draft tokens past the slot's arm depth — zeros, stale
        shallower sweeps — that a dedicated run never drafted, so they must
        not extend acceptance);
      - ``n_rank[b, i]`` = n_acc with winner-INELIGIBLE rows forced to -1,
        so ``argmax(n_rank)`` can never select them while every eligible
        row (n_acc >= 0) still outranks them.  Eligibility is the AND of
        ``i < k_eff[b]`` (linear arms: rows are ordered best-first, an arm
        keeps a prefix) and ``row_mask[b, i]`` (tree arms: a
        (width_b, depth_b) arm keeps the paths whose branch choices all lie
        below width_b — NOT a prefix of the lex-ordered path list).

    Degenerate masks behave like the dedicated run they mask down to:
    ``w_eff == 0`` zeroes every n_acc (plain greedy: row/path 0 wins, only
    the bonus token commits); ``k_eff == 1`` makes row 0 the only candidate;
    an all-False eq changes nothing (bonus-only step).  At least one row
    must stay eligible — k_eff >= 1 and a row_mask containing the all-0
    branch path guarantee that by construction.
    """
    B, k, w = eq.shape
    if w_eff is not None:
        eq = eq & (jnp.arange(w)[None, None, :] < w_eff[:, None, None])
    n_acc = jnp.cumprod(eq.astype(jnp.int32), axis=-1).sum(axis=-1)  # (B,k)
    eligible = jnp.ones((B, k), bool)
    if k_eff is not None:
        eligible = eligible & (jnp.arange(k)[None, :] < k_eff[:, None])
    if row_mask is not None:
        eligible = eligible & row_mask
    n_rank = jnp.where(eligible, n_acc, -1)
    return n_acc, n_rank


def accept(drafts: jnp.ndarray, greedy: jnp.ndarray,
           k_eff: Optional[jnp.ndarray] = None,
           w_eff: Optional[jnp.ndarray] = None,
           row_mask: Optional[jnp.ndarray] = None) -> Acceptance:
    """drafts: (B, k, w) int32; greedy: (B, k, w+1) int32 argmax predictions.

    ``k_eff`` (B,) / ``w_eff`` (B,) / ``row_mask`` (B, k) optionally mask
    slot b down to its arm's sub-problem (see ``masked_acceptance``): rows
    outside the arm are excluded from the winner argmax and acceptance
    stops at the arm depth (excluded rows' n_acc still reports the unmasked
    depth-truncated value for stats).  In tree mode the "rows" are
    root-to-leaf paths gathered from the verified node tree.
    """
    B, k, w = drafts.shape
    eq = drafts == greedy[..., :w]
    n_acc, n_rank = masked_acceptance(eq, k_eff=k_eff, w_eff=w_eff,
                                      row_mask=row_mask)
    winner = jnp.argmax(n_rank, axis=-1).astype(jnp.int32)           # (B,)
    n_win = jnp.take_along_axis(n_acc, winner[:, None], axis=1)[:, 0]
    d_win = jnp.take_along_axis(drafts, winner[:, None, None],
                                axis=1)[:, 0]                         # (B,w)
    g_win = jnp.take_along_axis(greedy, winner[:, None, None],
                                axis=1)[:, 0]                         # (B,w+1)
    pos = jnp.arange(w + 1)[None, :]
    bonus = jnp.take_along_axis(g_win, n_win[:, None], axis=1)        # (B,1)
    d_pad = jnp.concatenate([d_win, jnp.zeros((B, 1), d_win.dtype)], axis=1)
    tokens = jnp.where(pos < n_win[:, None], d_pad,
                       jnp.where(pos == n_win[:, None], bonus, 0))
    return Acceptance(tokens=tokens.astype(jnp.int32),
                      n_commit=(n_win + 1).astype(jnp.int32),
                      winner=winner, n_acc=n_acc)
