"""Figure 4 reproduction: strategy ablations for the mixed (10, 10) setup.

Top: distribution of acceptance lengths per call.
Middle: rank (winning row index) distribution among the top-k.
Bottom: allocation — how many of the k rows the context N-gram filled.
Plus the per-strategy accepted-token split (context vs extended bigram).
"""
from __future__ import annotations

import csv
import os

import numpy as np

from repro.core.spec_engine import SpecConfig

from .common import TASKS, ensure_dirs, get_tables, get_trained, measure


def run(out_dir: str = "experiments/results", max_new: int = 48) -> dict:
    ensure_dirs()
    cfg, params = get_trained()
    tables = get_tables(cfg, params)
    spec = SpecConfig(k=10, w=10, strategy="mixed", max_new_tokens=max_new)
    path = os.path.join(out_dir, "fig4_ablations.csv")
    summary = {}
    with open(path, "w", newline="") as f:
        wr = csv.writer(f)
        wr.writerow(["task", "histogram", "bin", "count"])
        for task in TASKS:
            r = measure(cfg, params, tables, task, spec, n_prompts=6)
            acc = r.stats["accept_hist"].sum(0)
            rank = r.stats["rank_hist"].sum(0)
            alloc = r.stats["alloc_ctx"].sum(0)
            for i, v in enumerate(acc):
                wr.writerow([task, "accept_len", i, int(v)])
            for i, v in enumerate(rank):
                wr.writerow([task, "winning_rank", i, int(v)])
            for i, v in enumerate(alloc):
                wr.writerow([task, "ctx_allocation", i, int(v)])
            n_ctx_tok = int(r.stats["accepted_ctx"].sum())
            n_big_tok = int(r.stats["accepted_bigram"].sum())
            wr.writerow([task, "accepted_by_strategy", "context", n_ctx_tok])
            wr.writerow([task, "accepted_by_strategy", "bigram", n_big_tok])
            mean_acc = (np.arange(len(acc)) * acc).sum() / max(acc.sum(), 1)
            summary[task] = dict(mean_accept=float(mean_acc),
                                 ctx_tokens=n_ctx_tok, bigram_tokens=n_big_tok,
                                 tokens_per_call=r.tokens_per_call)
    return {"csv": path, "summary": summary}


def main():
    res = run()
    print("fig4_ablations ->", res["csv"])
    for task, s in res["summary"].items():
        print(f"  {task:5s}: mean accept={s['mean_accept']:.2f} "
              f"ctx/bigram accepted={s['ctx_tokens']}/{s['bigram_tokens']} "
              f"tok/call={s['tokens_per_call']:.2f}")


if __name__ == "__main__":
    main()
