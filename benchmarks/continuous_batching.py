"""Continuous vs static batching under Poisson arrivals.

Requests arrive as a Poisson process (seeded, so runs are comparable) with
heterogeneous max_new_tokens; both serving modes run the same mixed-strategy
speculation.  Static batching admits work only when the scheduler forms a
batch and every row then rides until the slowest row finishes; continuous
batching admits/retires between jitted spec_steps.  Reported per mode:

  - throughput_tok_s : committed new tokens / busy wall time
  - p50/p99 latency  : submit -> completion per request (seconds)

Writes ``BENCH_continuous.json`` (repo root) so future PRs can track serving
throughput, and prints one CSV row per mode.

``--paged`` runs the PAGED long-context arrival mix instead (DESIGN.md §8):
mostly short prompts with periodic long-context ones, served by (a) linear
continuous batching, where every slot pays the long bucket's worst-case
buffer, and (b) paged continuous batching over a pool deliberately SMALLER
than that worst case (admission defers when exhausted).  Reported per mode:
throughput + latency as above, plus resident KV in token-positions per
layer (linear: max_batch * buf_size, always; paged: peak pages * page
size), deferral count and the leak check.  Writes ``BENCH_paged.json``.

``--adaptive`` benchmarks the in-flight adaptive (k, w) controller
(DESIGN.md §9) against every static arm of its table: the same Poisson
workload is served continuously once per static arm and once with
per-slot UCB arm masking, and the report gives each arm's throughput and
tokens/call plus the adaptive run's REGRET vs the best static arm (how
much throughput exploration cost) and its pull distribution.  Writes
``BENCH_adaptive.json``.

``--mesh DxM`` serves the SAME Poisson workload sharded over a debug mesh
(DESIGN.md §10) and against the 1-device engine: asserts bit-identical
outputs, reports tokens/s for both, and extracts the sharded spec_step's
per-step collective bytes from its optimized HLO (the dry-run's
``collective_bytes`` scraper — live serving now has the same collective
profile visibility as the 512-device dry-run).  Writes
``BENCH_sharded.json``.  On CPU the sharded run is a parity/plumbing
signal, not a speedup: all placeholder devices share one physical CPU.

Run:  PYTHONPATH=src python -m benchmarks.continuous_batching [--n 24]
      PYTHONPATH=src python -m benchmarks.continuous_batching --paged
      PYTHONPATH=src python -m benchmarks.continuous_batching --adaptive
      PYTHONPATH=src python -m benchmarks.continuous_batching --mesh 2x2
"""
from __future__ import annotations

if __name__ == "__main__":
    # --mesh needs placeholder devices BEFORE any jax import locks the
    # count (appended to XLA_FLAGS; a caller-provided count is respected)
    from repro.launch import hostdev
    hostdev.ensure_for_mesh_argv()

import argparse
import json
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.spec_engine import SpecConfig
from repro.data.datasets import make_prompts
from repro.launch import hostdev
from repro.serving import ServingEngine

from .common import get_tables, get_trained, ensure_dirs

BUCKETS = (64,)
MAX_NEW_CHOICES = (16, 32, 48)


def make_workload(n: int, rate_hz: float, seed: int = 0
                  ) -> List[Tuple[str, int, float]]:
    """(prompt, max_new_tokens, arrival_time_s) sorted by arrival."""
    rng = np.random.default_rng(seed)
    texts = [p for p, _ in make_prompts("code", (n + 1) // 2, seed=1)]
    texts += [p for p, _ in make_prompts("math", n - len(texts), seed=2)]
    gaps = rng.exponential(1.0 / rate_hz, n)
    arrivals = np.cumsum(gaps)
    return [(texts[i], int(rng.choice(MAX_NEW_CHOICES)), float(arrivals[i]))
            for i in range(n)]


def _summary(lat: Dict[int, float], toks: int, busy_s: float,
             calls: int = 0, hist: Optional[List[int]] = None) -> Dict:
    ls = np.asarray(sorted(lat.values()))
    out = {"requests": len(ls),
           "new_tokens": toks,
           "busy_wall_s": round(busy_s, 3),
           "throughput_tok_s": round(toks / max(busy_s, 1e-9), 2),
           "p50_latency_s": round(float(np.percentile(ls, 50)), 4),
           "p99_latency_s": round(float(np.percentile(ls, 99)), 4)}
    if calls:
        out["tokens_per_call"] = round(toks / calls, 3)
    if hist is not None:
        out["accept_hist"] = hist
    return out


def _add_hist(agg: List[int], h: List[int]) -> List[int]:
    """Element-wise sum of acceptance-length histograms (index = tokens
    committed by one verify call, 0..w+1); ragged lengths zero-extend so
    mixed (k, w) runs — adaptive arms, warm restarts — still aggregate."""
    if len(h) > len(agg):
        agg = agg + [0] * (len(h) - len(agg))
    return [a + (h[i] if i < len(h) else 0) for i, a in enumerate(agg)]


def run_static(eng, workload) -> Dict:
    """Replay arrivals against static batching: whenever requests are queued,
    form the next batch and run it to completion (the queue keeps growing
    while the monolithic generate blocks)."""
    pending = list(workload)
    arrival: Dict[int, float] = {}
    latency: Dict[int, float] = {}
    toks = 0
    calls = 0
    hist: List[int] = []
    busy = 0.0
    t0 = time.perf_counter()
    while pending or eng.scheduler.pending():
        now = time.perf_counter() - t0
        while pending and pending[0][2] <= now:
            text, mnt, at = pending.pop(0)
            arrival[eng.submit(text, max_new_tokens=mnt).request_id] = at
        batch = eng.scheduler.next_batch()
        if batch is None:
            # nothing runnable yet: jump to the next arrival
            time.sleep(min(0.001, max(pending[0][2] - now, 0.0)))
            continue
        tb = time.perf_counter()
        reqs = eng.run_batch(batch)
        busy += time.perf_counter() - tb
        done_t = time.perf_counter() - t0
        for r in reqs:
            latency[r.request_id] = done_t - arrival[r.request_id]
            toks += r.stats["new_tokens"]
            calls += r.stats.get("model_calls", 0)
            hist = _add_hist(hist, r.stats.get("accept_hist", []))
    return _summary(latency, toks, busy, calls, hist)


def run_continuous(eng, workload,
                   out_ids: Optional[Dict[int, list]] = None) -> Dict:
    pending = list(workload)
    arrival: Dict[int, float] = {}
    order: Dict[int, int] = {}          # request_id -> submission ordinal
    latency: Dict[int, float] = {}
    toks = 0
    calls = 0
    hist: List[int] = []
    busy = 0.0
    t0 = time.perf_counter()
    while pending or eng.scheduler.pending() or eng.in_flight():
        now = time.perf_counter() - t0
        while pending and pending[0][2] <= now:
            text, mnt, at = pending.pop(0)
            rid = eng.submit(text, max_new_tokens=mnt).request_id
            arrival[rid] = at
            order[rid] = len(order)
        if not (eng.scheduler.pending() or eng.in_flight()):
            time.sleep(min(0.001, max(pending[0][2] - now, 0.0)))
            continue
        tb = time.perf_counter()
        retired = eng.step()
        busy += time.perf_counter() - tb
        done_t = time.perf_counter() - t0
        for r in retired:
            latency[r.request_id] = done_t - arrival[r.request_id]
            toks += r.stats["new_tokens"]
            calls += r.stats.get("model_calls", 0)
            hist = _add_hist(hist, r.stats.get("accept_hist", []))
            if out_ids is not None:
                # keyed by SUBMISSION ordinal (request_ids are process-
                # global), so runs of the same workload compare directly
                # (the sharded-vs-baseline parity check)
                out_ids[order[r.request_id]] = \
                    np.asarray(r.output_ids).tolist()
    return _summary(latency, toks, busy, calls, hist)


# ---------------------------------------------------------------------------
# paged long-context mix (--paged): BENCH_paged.json
# ---------------------------------------------------------------------------
PAGED_BUCKETS = (64, 256)        # short bucket + the long-context bucket
PAGED_PAGE_SIZE = 32
LONG_EVERY = 5                   # every 5th arrival is long-context


def make_longctx_workload(n: int, rate_hz: float, seed: int = 0
                          ) -> List[Tuple[str, int, float]]:
    """Arrival mix where every LONG_EVERY-th request needs the long bucket
    (the rest fit the short one) — the admission pattern paged serving is
    for: shorts must keep flowing around the page-hungry requests."""
    rng = np.random.default_rng(seed)
    texts = [p for p, _ in make_prompts("code", n, seed=1)]
    gaps = rng.exponential(1.0 / rate_hz, n)
    arrivals = np.cumsum(gaps)
    out = []
    for i in range(n):
        text = texts[i % len(texts)]
        if i % LONG_EVERY == 2:                              # long-context
            text = ((text + " ") * 40)[:PAGED_BUCKETS[-1] - 1]
        else:
            text = text[:PAGED_BUCKETS[0] - 1]
        # -1: ByteTokenizer prepends BOS, and the engine rejects raw token
        # counts beyond the largest bucket (that rejection path has its own
        # test; here every request must actually run)
        out.append((text, int(rng.choice(MAX_NEW_CHOICES)),
                    float(arrivals[i])))
    return out


def run_paged(n: int = 24, rate_hz: float = 4.0, max_batch: int = 4,
              seed: int = 0) -> Dict:
    ensure_dirs()
    cfg, params = get_trained()
    tables = get_tables(cfg, params, k_max=16, w_max=10)
    cap = max(MAX_NEW_CHOICES)
    spec = SpecConfig(k=8, w=8, strategy="mixed", max_new_tokens=cap)
    ps = PAGED_PAGE_SIZE
    # linear worst case: every slot carries the long bucket's buffer
    buf_tokens = PAGED_BUCKETS[-1] + cap + spec.w + 2
    linear_equiv_pages = max_batch * (-(-buf_tokens // ps))
    num_pages = int(linear_equiv_pages * 0.6)    # the pool linear can't match

    def make_engine(paged: bool):
        return ServingEngine(params, cfg, spec, tables=tables,
                             max_batch=max_batch, buckets=PAGED_BUCKETS,
                             max_new_cap=cap, paged=paged,
                             num_pages=num_pages if paged else None,
                             page_size=ps)

    res = {"workload": {"n": n, "rate_hz": rate_hz, "seed": seed,
                        "max_batch": max_batch,
                        "buckets": list(PAGED_BUCKETS),
                        "long_every": LONG_EVERY, "page_size": ps,
                        "num_pages": num_pages,
                        "linear_equiv_pages": linear_equiv_pages,
                        "spec": {"k": spec.k, "w": spec.w,
                                 "strategy": spec.strategy}}}
    for mode in ("linear", "paged"):
        eng = make_engine(paged=(mode == "paged"))
        for text in ("warmup", "w" * (PAGED_BUCKETS[-1] - 1)):  # both buckets
            for mnt in MAX_NEW_CHOICES:
                eng.submit(text, max_new_tokens=mnt)
            eng.serve_continuous()
        if mode == "paged":
            eng.reset_pool_counters()   # peak/deferrals measure the
                                        # workload, not the warmup
        summary = run_continuous(eng, make_longctx_workload(n, rate_hz,
                                                            seed))
        if mode == "paged":
            pool = eng.pool_stats()
            assert pool["free_pages"] == pool["num_pages"], (
                f"leaked pages: {pool}")
            assert pool["rejected"] == 0, (
                f"workload must fit the buckets, got rejections: {pool}")
            summary.update(
                peak_kv_tokens=pool["peak_pages"] * ps,
                pool_pages=pool["num_pages"],
                peak_pages=pool["peak_pages"],
                admission_deferrals=pool["deferrals"],
                rejected=pool["rejected"],
                leaked_pages=pool["num_pages"] - pool["free_pages"])
        else:
            # linear residency is static: every slot, whole buffer, always
            summary.update(
                peak_kv_tokens=max_batch * eng._cont_state.buf_size)
        res[mode] = summary
    with open("BENCH_paged.json", "w") as f:
        json.dump(res, f, indent=1)
    return res


# ---------------------------------------------------------------------------
# adaptive (k, w) regret vs the best static arm (--adaptive): BENCH_adaptive
# ---------------------------------------------------------------------------
# a compact arm ladder: greedy, a cheap shallow arm, the paper's sweet spot
# region, and an aggressive deep arm (kept small so the CPU nightly finishes)
ADAPT_ARMS = ((1, 0), (4, 2), (8, 4), (8, 8))


def run_adaptive(n: int = 24, rate_hz: float = 4.0, max_batch: int = 4,
                 seed: int = 0) -> Dict:
    ensure_dirs()
    cfg, params = get_trained()
    arm_k = max(a[0] for a in ADAPT_ARMS)
    arm_w = max(a[1] for a in ADAPT_ARMS)
    tables = get_tables(cfg, params, k_max=max(16, arm_k),
                        w_max=max(10, arm_w))
    cap = max(MAX_NEW_CHOICES)

    def make_engine(arm=None):
        """arm=None: the adaptive engine; else one static-arm engine."""
        if arm is None:
            spec = SpecConfig(k=arm_k, w=arm_w, strategy="mixed",
                              max_new_tokens=cap)
            return ServingEngine(params, cfg, spec, tables=tables,
                                 max_batch=max_batch, buckets=BUCKETS,
                                 max_new_cap=cap, adaptive=True,
                                 arms=ADAPT_ARMS)
        k, w = arm
        spec = (SpecConfig(strategy="greedy", max_new_tokens=cap) if w == 0
                else SpecConfig(k=k, w=w, strategy="mixed",
                                max_new_tokens=cap))
        return ServingEngine(params, cfg, spec, tables=tables,
                             max_batch=max_batch, buckets=BUCKETS,
                             max_new_cap=cap)

    res = {"workload": {"n": n, "rate_hz": rate_hz, "seed": seed,
                        "max_batch": max_batch, "buckets": list(BUCKETS),
                        "arms": [list(a) for a in ADAPT_ARMS]},
           "static_arms": {}}
    workload = make_workload(n, rate_hz, seed)
    for arm in ADAPT_ARMS:
        eng = make_engine(arm)
        eng.submit("warmup", max_new_tokens=min(MAX_NEW_CHOICES))
        eng.serve_continuous()
        res["static_arms"][f"k{arm[0]}_w{arm[1]}"] = run_continuous(
            eng, workload)
    eng = make_engine()
    eng.submit("warmup", max_new_tokens=min(MAX_NEW_CHOICES))
    eng.serve_continuous()
    eng.reset_pool_counters()     # pull counts measure the workload window
    adaptive = run_continuous(eng, workload)
    pulls = eng.adaptive_stats()["pulls_retired"]
    adaptive["arm_pulls"] = pulls
    res["adaptive"] = adaptive
    # Two regret views.  (a) raw wall-clock: on CPU this structurally
    # favours the small static arms, because the masked step always pays
    # the (k_max, w_max)-shaped verify compute whichever arm a slot picks —
    # the roofline says exactly those extra rows/positions are bandwidth-
    # free on TPU, which is the hardware the masking trades for.  (b) the
    # bandit's own objective, tokens-per-call / roofline slowdown: per-arm
    # scores for the static runs vs the adaptive run's pull-weighted
    # realized score — hardware-independent, and the number that should
    # approach zero regret as the workload grows.
    from repro.core.controller import arm_slowdowns
    slow = arm_slowdowns(cfg, ADAPT_ARMS)
    scores = {}
    for arm, s in zip(ADAPT_ARMS, slow):
        r = res["static_arms"][f"k{arm[0]}_w{arm[1]}"]
        scores[f"k{arm[0]}_w{arm[1]}"] = round(
            r["tokens_per_call"] / s, 4)
    w_slow = (sum(p * s for p, s in zip(pulls, slow))
              / max(sum(pulls), 1))
    adaptive_score = round(adaptive["tokens_per_call"] / w_slow, 4)
    best_arm, best = max(res["static_arms"].items(),
                         key=lambda kv: kv[1]["throughput_tok_s"])
    best_score_arm = max(scores, key=scores.get)
    res["regret"] = {
        "best_static_arm_wallclock": best_arm,
        "throughput_regret_tok_s": round(
            best["throughput_tok_s"] - adaptive["throughput_tok_s"], 2),
        "modeled_scores": scores,
        "adaptive_modeled_score": adaptive_score,
        "best_static_arm_modeled": best_score_arm,
        # positive = exploration cost; near zero = the bandit matched the
        # best static arm under its objective
        "modeled_regret": round(scores[best_score_arm] - adaptive_score, 4),
        "modeled_regret_frac": round(
            1.0 - adaptive_score / max(scores[best_score_arm], 1e-9), 4)}
    with open("BENCH_adaptive.json", "w") as f:
        json.dump(res, f, indent=1)
    return res


# ---------------------------------------------------------------------------
# sharded continuous serving over a debug mesh (--mesh): BENCH_sharded.json
# ---------------------------------------------------------------------------
def run_mesh(mesh_shape, n: int = 24, rate_hz: float = 4.0,
             max_batch: int = 4, seed: int = 0) -> Dict:
    ensure_dirs()
    from repro.launch.dryrun import collective_bytes
    from repro.launch.mesh import make_debug_mesh
    mesh = make_debug_mesh(mesh_shape)
    cfg, params = get_trained()
    tables = get_tables(cfg, params, k_max=16, w_max=10)
    spec = SpecConfig(k=8, w=8, strategy="mixed",
                      max_new_tokens=max(MAX_NEW_CHOICES))

    def make_engine(mesh_arg):
        return ServingEngine(params, cfg, spec, tables=tables,
                             max_batch=max_batch, buckets=BUCKETS,
                             max_new_cap=max(MAX_NEW_CHOICES), mesh=mesh_arg)

    res = {"workload": {"n": n, "rate_hz": rate_hz, "seed": seed,
                        "max_batch": max_batch, "buckets": list(BUCKETS),
                        "spec": {"k": spec.k, "w": spec.w,
                                 "strategy": spec.strategy}},
           "mesh": "x".join(str(d) for d in mesh_shape)}
    workload = make_workload(n, rate_hz, seed)
    outputs = {}
    for mode, mesh_arg in (("baseline_1dev", None), ("sharded", mesh)):
        eng = make_engine(mesh_arg)
        eng.submit("warmup", max_new_tokens=min(MAX_NEW_CHOICES))
        eng.serve_continuous()
        outs: Dict[int, list] = {}
        summary = run_continuous(eng, workload, out_ids=outs)
        outputs[mode] = outs
        if mode == "sharded":
            rep = eng.mesh_report()
            assert rep["state_sharded"] > 0 and rep["params_sharded"] > 0, (
                f"mesh {res['mesh']} sharded NOTHING — "
                f"fallbacks: {rep['replication_fallbacks']}")
            summary["mesh_report"] = rep
            # per-step collective profile of the live sharded spec_step —
            # the quantity the 512-device dry-run reports, now for serving
            summary["collectives_per_step"] = collective_bytes(
                eng.step_hlo())
        res[mode] = summary
    # the whole point: sharded serving is bit-identical, token for token
    assert outputs["baseline_1dev"] == outputs["sharded"], (
        "sharded serving diverged from the 1-device baseline")
    res["parity"] = "bit-exact"
    with open("BENCH_sharded.json", "w") as f:
        json.dump(res, f, indent=1)
    return res


# ---------------------------------------------------------------------------
# tree vs linear speculation at matched verify-call cost (--tree): BENCH_tree
# ---------------------------------------------------------------------------
# Verify-call cost = query positions scored per call: k*(w+1) for linear
# batched rows (every row re-scores the shared root), num_nodes+1 for a
# tree (the ancestor mask scores the root ONCE).  That root dedup is the
# measured tree lever on this byte-level model: a branch-1 tree (width, d)
# carries the exact acceptance behaviour of linear (k=width, w=d) for
# width-1 fewer positions, and spending the savings on extra width/depth
# beats the best same-cost linear reshape (probed against a dense
# (k, w) frontier, 4 workload seeds).  Multi-level branching (b >= 2)
# costs width^2 positions per branched level, which byte-level branching
# entropy never pays back — kept as one arm so the JSON documents that
# verdict honestly (negative advantage).
#
# Pairs put the tree at most ONE position above its linear partner:
#   tree w10 d3 b1 =  31  vs  linear (6, 4)  = 30  (best linear <= 31)
#   tree w14 d5 b1 =  71  vs  linear (12, 5) = 72  (best linear <= 72)
#   tree w16 d5 b1 =  81  vs  linear (16, 4) = 80  (best linear <= 81)
#   tree w4  d5 b2 =  69  vs  linear (12, 5) = 72  (branching verdict)
LINEAR_ARMS = ((4, 4), (6, 4), (5, 5), (12, 5), (14, 4), (16, 4), (16, 8))
TREE_ARMS = ((10, 3, 1), (14, 5, 1), (16, 5, 1), (4, 5, 2))
TREE_PAIRS = (("tree_w10_d3_b1", "linear_k6_w4"),
              ("tree_w14_d5_b1", "linear_k12_w5"),
              ("tree_w16_d5_b1", "linear_k16_w4"),
              ("tree_w4_d5_b2", "linear_k12_w5"))
TREE_BUCKET = 128


def make_repetitive_prompts(n: int, seed: int = 0) -> List[str]:
    """Repetitive mix with BRANCHING ambiguity — the workload trees are for.

    Half the prompts loop one chunk verbatim (pure repetition: n-gram
    drafters chain the tail, any k works).  The other half alternate TWO
    chunks sharing a prefix, so at the seam the top-1 n-gram successor is
    right only half the time while the top-2 set always contains the truth:
    a linear draft burns a whole row per guess, a width>=2 tree covers both
    and keeps chaining below each."""
    rng = np.random.default_rng(seed)
    texts = [p for p, _ in make_prompts("code", n, seed=1)]
    out = []
    for i, t in enumerate(texts):
        a = t[:14].strip() or "for i in"
        if i % 2 == 0:
            body = (a + " ") * 8                          # pure repetition
        else:
            b = (a[:6] + t[20:28]).strip() or a + "x"     # shared prefix
            body = "".join((a if j % 2 else b) + " " for j in range(8))
        out.append(body[:TREE_BUCKET - 1])
    return out


def run_tree(n: int = 12, max_new: int = 48, max_batch: int = 4,
             seed: int = 0) -> Dict:
    ensure_dirs()
    from repro.core.tree import num_nodes
    cfg, params = get_trained()
    tables = get_tables(cfg, params, k_max=16, w_max=10)
    prompts = make_repetitive_prompts(n, seed)

    def serve(spec) -> Tuple[Dict, List[list]]:
        eng = ServingEngine(params, cfg, spec, tables=tables,
                            max_batch=max_batch, buckets=(TREE_BUCKET,),
                            max_new_cap=max_new)
        for p in prompts:
            eng.submit(p, max_new_tokens=max_new)
        t0 = time.perf_counter()
        reqs = sorted(eng.serve_all(), key=lambda r: r.request_id)
        wall = time.perf_counter() - t0
        toks = sum(r.stats["new_tokens"] for r in reqs)
        calls = sum(r.stats["model_calls"] for r in reqs)
        hist: List[int] = []
        for r in reqs:
            hist = _add_hist(hist, r.stats.get("accept_hist", []))
        summary = {"new_tokens": toks, "model_calls": calls,
                   "tokens_per_call": round(toks / max(calls, 1), 3),
                   "wall_s": round(wall, 3),
                   "accept_hist": hist}
        return summary, [np.asarray(r.output_ids).tolist() for r in reqs]

    res = {"workload": {"n": n, "max_new": max_new, "max_batch": max_batch,
                        "seed": seed, "bucket": TREE_BUCKET,
                        "mix": "repetitive + 2-way branching seams"},
           "configs": {}}
    # greedy reference once: every speculative config below must reproduce
    # it token for token (tree mode is lossless, not approximate)
    _, ref_out = serve(SpecConfig(strategy="greedy", max_new_tokens=max_new))
    for k, w in LINEAR_ARMS:
        s, out = serve(SpecConfig(k=k, w=w, strategy="mixed",
                                  max_new_tokens=max_new))
        assert out == ref_out, f"linear ({k},{w}) diverged from greedy"
        s["verify_cost"] = k * (w + 1)
        res["configs"][f"linear_k{k}_w{w}"] = s
    for wd, dp, br in TREE_ARMS:
        s, out = serve(SpecConfig(k=wd, w=dp, strategy="mixed",
                                  max_new_tokens=max_new,
                                  tree=True, tree_branch=br))
        assert out == ref_out, f"tree ({wd},{dp},{br}) diverged from greedy"
        s["verify_cost"] = num_nodes(wd, dp, br) + 1
        res["configs"][f"tree_w{wd}_d{dp}_b{br}"] = s
    res["parity"] = "bit-exact vs greedy"
    res["pairs"] = []
    for tname, lname in TREE_PAIRS:
        t, l = res["configs"][tname], res["configs"][lname]
        res["pairs"].append({
            "tree": tname, "linear": lname,
            "tree_cost": t["verify_cost"], "linear_cost": l["verify_cost"],
            "tree_tokens_per_call": t["tokens_per_call"],
            "linear_tokens_per_call": l["tokens_per_call"],
            "tree_advantage": round(
                t["tokens_per_call"] - l["tokens_per_call"], 3)})
    best_lin = max((r["tokens_per_call"] for name, r in
                    res["configs"].items() if name.startswith("linear")))
    best_tree = max((r["tokens_per_call"] for name, r in
                     res["configs"].items() if name.startswith("tree")))
    res["best_linear_tokens_per_call"] = best_lin
    res["best_tree_tokens_per_call"] = best_tree
    # headline: each tree vs the BEST linear arm it could have been traded
    # for (any linear arm costing at most one position more), not just its
    # named partner — a tree only counts as winning if no same-budget
    # linear reshape beats it
    res["headline"] = []
    for name, t in res["configs"].items():
        if not name.startswith("tree"):
            continue
        elig = {ln: l for ln, l in res["configs"].items()
                if ln.startswith("linear")
                and l["verify_cost"] <= t["verify_cost"] + 1}
        bn = max(elig, key=lambda ln: elig[ln]["tokens_per_call"])
        res["headline"].append({
            "tree": name, "tree_cost": t["verify_cost"],
            "tree_tokens_per_call": t["tokens_per_call"],
            "best_linear_at_cost": bn,
            "best_linear_cost": elig[bn]["verify_cost"],
            "best_linear_tokens_per_call": elig[bn]["tokens_per_call"],
            "advantage": round(t["tokens_per_call"]
                               - elig[bn]["tokens_per_call"], 3)})
    with open("BENCH_tree.json", "w") as f:
        json.dump(res, f, indent=1)
    return res


# ---------------------------------------------------------------------------
# lossless speculative sampling (--temperature): BENCH_sampling.json
# ---------------------------------------------------------------------------
def run_sampling(n: int, rate_hz: float, max_batch: int, seed: int,
                 temperature: float, top_p: float) -> Dict:
    """Mixed greedy/sampled continuous serving (DESIGN.md §12): every even
    submission decodes greedy, every odd one samples at ``--temperature`` /
    ``--top-p``, all through ONE sampling-enabled spec_step.  Reports
    tokens/call + acceptance histograms PER temperature CLASS (how much
    speculation survives rejection sampling vs the greedy walk), and
    asserts the greedy class is bit-identical to the same requests served
    by a pure-greedy engine — the lossless contract under mixed serving.
    Sampled requests pin per-ordinal seeds, so reruns replay exactly."""
    ensure_dirs()
    cfg, params = get_trained()
    tables = get_tables(cfg, params, k_max=16, w_max=10)
    spec = SpecConfig(k=8, w=8, strategy="mixed",
                      max_new_tokens=max(MAX_NEW_CHOICES))

    def make_engine(sampling: bool):
        return ServingEngine(params, cfg, spec, tables=tables,
                             max_batch=max_batch, buckets=BUCKETS,
                             max_new_cap=max(MAX_NEW_CHOICES),
                             sampling=sampling, seed=seed)

    workload = make_workload(n, rate_hz, seed)

    def serve(eng, classes: List[str]):
        pending = list(enumerate(workload))
        out: Dict[int, list] = {}
        cls: Dict[str, Dict] = {c: {"requests": 0, "new_tokens": 0,
                                    "model_calls": 0, "accept_hist": []}
                                for c in set(classes)}
        rid2ord: Dict[int, int] = {}
        busy = 0.0
        t0 = time.perf_counter()
        while pending or eng.scheduler.pending() or eng.in_flight():
            now = time.perf_counter() - t0
            while pending and pending[0][1][2] <= now:
                i, (text, mnt, _) = pending.pop(0)
                temp = temperature if classes[i] == "sampled" else 0.0
                rid = eng.submit(text, max_new_tokens=mnt,
                                 temperature=temp,
                                 top_p=top_p if temp > 0 else 1.0,
                                 seed=10_000 + i).request_id
                rid2ord[rid] = i
            if not (eng.scheduler.pending() or eng.in_flight()):
                time.sleep(min(0.001, max(pending[0][1][2] - now, 0.0)))
                continue
            tb = time.perf_counter()
            retired = eng.step()
            busy += time.perf_counter() - tb
            for r in retired:
                i = rid2ord[r.request_id]
                out[i] = np.asarray(r.output_ids).tolist()
                c = cls[classes[i]]
                c["requests"] += 1
                c["new_tokens"] += r.stats["new_tokens"]
                c["model_calls"] += r.stats.get("model_calls", 0)
                c["accept_hist"] = _add_hist(
                    c["accept_hist"], r.stats.get("accept_hist", []))
        for c in cls.values():
            c["tokens_per_call"] = round(
                c["new_tokens"] / max(c["model_calls"], 1), 3)
        return out, cls, busy

    classes = ["greedy" if i % 2 == 0 else "sampled" for i in range(n)]
    eng = make_engine(True)
    eng.submit("warmup", max_new_tokens=min(MAX_NEW_CHOICES),
               temperature=temperature, top_p=top_p)
    eng.serve_continuous()
    out_mixed, cls_stats, busy = serve(eng, classes)

    # lossless check: the greedy-class rows must be bit-identical to the
    # same requests on a PINNED pure-greedy engine (whose step executable
    # is byte-identical to the pre-sampling engine)
    eng_g = make_engine(False)
    eng_g.submit("warmup", max_new_tokens=min(MAX_NEW_CHOICES))
    eng_g.serve_continuous()
    out_greedy, _, _ = serve(eng_g, ["greedy"] * n)
    lossless = all(out_mixed[i] == out_greedy[i]
                   for i in range(n) if classes[i] == "greedy")
    total = sum(c["new_tokens"] for c in cls_stats.values())
    res = {"workload": {"n": n, "rate_hz": rate_hz, "seed": seed,
                        "max_batch": max_batch, "buckets": list(BUCKETS),
                        "temperature": temperature, "top_p": top_p,
                        "spec": {"k": spec.k, "w": spec.w,
                                 "strategy": spec.strategy}},
           "classes": cls_stats,
           "busy_wall_s": round(busy, 3),
           "throughput_tok_s": round(total / max(busy, 1e-9), 2),
           "greedy_class_lossless": bool(lossless)}
    with open("BENCH_sampling.json", "w") as f:
        json.dump(res, f, indent=1)
    return res


def run(n: int = 24, rate_hz: float = 4.0, max_batch: int = 4,
        seed: int = 0) -> Dict:
    ensure_dirs()
    cfg, params = get_trained()
    tables = get_tables(cfg, params, k_max=16, w_max=10)
    spec = SpecConfig(k=8, w=8, strategy="mixed",
                      max_new_tokens=max(MAX_NEW_CHOICES))

    def make_engine():
        return ServingEngine(params, cfg, spec, tables=tables,
                             max_batch=max_batch, buckets=BUCKETS,
                             max_new_cap=max(MAX_NEW_CHOICES))

    # warm the jit caches of the engines we measure, out of the timed region
    # (generate is compiled per (engine, max_new); spec_step/admit_slot are
    # module-level and compile once per shape)
    # static generate compiles per (batch_size, max_new): warm every combo
    # the replay can produce, else compile time pollutes the busy window
    eng_static = make_engine()
    for mnt in MAX_NEW_CHOICES:
        for b in range(1, max_batch + 1):
            for _ in range(b):
                eng_static.submit("warmup", max_new_tokens=mnt)
            eng_static.serve_all()
    eng_cont = make_engine()
    eng_cont.submit("warmup", max_new_tokens=min(MAX_NEW_CHOICES))
    eng_cont.serve_continuous()

    workload = make_workload(n, rate_hz, seed)
    res = {"workload": {"n": n, "rate_hz": rate_hz, "seed": seed,
                        "max_batch": max_batch, "buckets": list(BUCKETS),
                        "spec": {"k": spec.k, "w": spec.w,
                                 "strategy": spec.strategy}},
           "static": run_static(eng_static, workload),
           "continuous": run_continuous(eng_cont, workload)}
    with open("BENCH_continuous.json", "w") as f:
        json.dump(res, f, indent=1)
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=24)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="run the paged long-context arrival mix and write "
                         "BENCH_paged.json (linear vs paged KV layouts)")
    ap.add_argument("--adaptive", action="store_true",
                    help="benchmark per-slot adaptive (k, w) continuous "
                         "serving against every static arm of its table "
                         "and write BENCH_adaptive.json (regret report)")
    ap.add_argument("--mesh", default="",
                    help="serve the workload sharded over a DxM debug mesh "
                         "(e.g. 2x2) vs the 1-device engine, assert bit "
                         "parity, report per-step collective bytes, and "
                         "write BENCH_sharded.json")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="serve a mixed greedy/sampled workload (half the "
                         "requests sample at this temperature) through one "
                         "sampling-enabled spec_step and write "
                         "BENCH_sampling.json (per-class tokens/call + "
                         "acceptance hists + greedy-class lossless "
                         "assertion, DESIGN.md §12)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus mass for the sampled class of "
                         "--temperature (1 = off)")
    ap.add_argument("--tree", action="store_true",
                    help="benchmark tree-structured speculation against "
                         "linear batched rows at matched verify-call cost "
                         "on the repetitive/branching mix and write "
                         "BENCH_tree.json (DESIGN.md §11)")
    args = ap.parse_args()
    if args.temperature > 0:
        res = run_sampling(args.n, args.rate, args.max_batch, args.seed,
                           args.temperature, args.top_p)
        print("class,requests,tokens_per_call,accept_hist")
        for name in ("greedy", "sampled"):
            c = res["classes"][name]
            print(f"{name},{c['requests']},{c['tokens_per_call']},"
                  f"\"{c['accept_hist']}\"")
        print(f"throughput {res['throughput_tok_s']} tok/s | greedy class "
              f"lossless: {res['greedy_class_lossless']}")
        if not res["greedy_class_lossless"]:
            raise SystemExit("greedy-class rows diverged from the pure-"
                             "greedy engine: lossless contract broken")
        print("wrote BENCH_sampling.json")
        return
    if args.tree:
        res = run_tree(max(args.n, 4), max_batch=args.max_batch,
                       seed=args.seed)
        print("config,verify_cost,tokens_per_call,accept_hist")
        for name, r in res["configs"].items():
            print(f"{name},{r['verify_cost']},{r['tokens_per_call']},"
                  f"\"{r['accept_hist']}\"")
        for p in res["pairs"]:
            print(f"pair {p['tree']} (cost {p['tree_cost']}) vs "
                  f"{p['linear']} (cost {p['linear_cost']}): "
                  f"{p['tree_tokens_per_call']} vs "
                  f"{p['linear_tokens_per_call']} tokens/call "
                  f"(advantage {p['tree_advantage']:+.3f})")
        for h in res["headline"]:
            print(f"headline {h['tree']} (cost {h['tree_cost']}) vs best "
                  f"same-cost linear {h['best_linear_at_cost']} "
                  f"(cost {h['best_linear_cost']}): "
                  f"{h['tree_tokens_per_call']} vs "
                  f"{h['best_linear_tokens_per_call']} tokens/call "
                  f"(advantage {h['advantage']:+.3f})")
        print(f"parity: {res['parity']}")
        print("wrote BENCH_tree.json")
        return
    if args.mesh:
        res = run_mesh(hostdev.parse_mesh_shape(args.mesh), args.n,
                       args.rate, args.max_batch, args.seed)
        print("mode,throughput_tok_s,tokens_per_call,p50_latency_s")
        for mode in ("baseline_1dev", "sharded"):
            r = res[mode]
            print(f"{mode},{r['throughput_tok_s']},"
                  f"{r.get('tokens_per_call', 0)},{r['p50_latency_s']}")
        coll = res["sharded"]["collectives_per_step"]
        rep = res["sharded"]["mesh_report"]
        counts = {k: v for k, v in coll["counts"].items() if v}
        print(f"parity: {res['parity']} | collective bytes/step "
              f"{coll['total']} {counts} | params sharded "
              f"{rep['params_sharded']}/{rep['params_leaves']} | "
              f"state leaves sharded {rep['state_sharded']}")
        print("wrote BENCH_sharded.json")
        return
    if args.adaptive:
        res = run_adaptive(args.n, args.rate, args.max_batch, args.seed)
        print("mode,throughput_tok_s,tokens_per_call,p50_latency_s")
        for name, r in res["static_arms"].items():
            print(f"{name},{r['throughput_tok_s']},"
                  f"{r.get('tokens_per_call', 0)},{r['p50_latency_s']}")
        r = res["adaptive"]
        print(f"adaptive,{r['throughput_tok_s']},"
              f"{r.get('tokens_per_call', 0)},{r['p50_latency_s']}")
        rg = res["regret"]
        print(f"modeled scores (tokens/call / roofline slowdown): "
              f"{rg['modeled_scores']} | adaptive "
              f"{rg['adaptive_modeled_score']} -> modeled regret "
              f"{rg['modeled_regret']} ({rg['modeled_regret_frac']:.1%} "
              f"of best arm {rg['best_static_arm_modeled']})")
        print(f"adaptive arm pulls: {r['arm_pulls']}")
        print("wrote BENCH_adaptive.json")
        return
    if args.paged:
        res = run_paged(args.n, args.rate, args.max_batch, args.seed)
        print("mode,throughput_tok_s,p50_latency_s,p99_latency_s,"
              "peak_kv_tokens,admission_deferrals")
        for mode in ("linear", "paged"):
            r = res[mode]
            print(f"{mode},{r['throughput_tok_s']},{r['p50_latency_s']},"
                  f"{r['p99_latency_s']},{r['peak_kv_tokens']},"
                  f"{r.get('admission_deferrals', 0)}")
        print("wrote BENCH_paged.json")
        return
    res = run(args.n, args.rate, args.max_batch, args.seed)
    print("mode,throughput_tok_s,p50_latency_s,p99_latency_s")
    for mode in ("static", "continuous"):
        r = res[mode]
        print(f"{mode},{r['throughput_tok_s']},{r['p50_latency_s']},"
              f"{r['p99_latency_s']}")
    print("wrote BENCH_continuous.json")


if __name__ == "__main__":
    main()
