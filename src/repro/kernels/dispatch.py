"""Kernel-dispatch layer: ONE switch between the Pallas fast path and XLA.

Every hot-path consumer (``models/attention.py:attn_verify``,
``core/drafters.py:context_ngram_draft``, the serving engine's buffer
sizing) routes through this module instead of importing kernels directly,
so backend selection, interpret-mode forcing and cache-length alignment are
decided in exactly one place.

Backend knob (``ModelConfig.backend`` for attention, ``SpecConfig.backend``
for drafting): ``"xla" | "pallas" | "auto"``.

  - ``"auto"``   — pallas on TPU, xla everywhere else (the production
                   default: the kernels are written for the TPU memory
                   hierarchy; on CPU the XLA paths are faster than
                   interpret-mode emulation).
  - ``"pallas"`` — always run the Pallas kernels.  Off-TPU this forces
                   ``interpret=True`` (how the parity tests prove the
                   kernels bit-compatible with the XLA paths on CPU).
  - ``"xla"``    — always run the pure-XLA paths.

Alignment: ``spec_attention_op`` streams the KV cache in ``block_s``-slot
VMEM blocks and pads the cache up to a block multiple per call when the
physical length does not divide — ``align_cache_len`` gives serving the
buffer length at which that per-step repad never happens.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ops, ref

BACKENDS = ("xla", "pallas", "auto")
LANE = 128          # TPU lane width: last-dim tile of every VMEM block
SUBLANE = 8         # f32 sublane width: second-to-last-dim tile


def resolve_backend(backend: str) -> str:
    """Map the config knob to a concrete backend ("xla" or "pallas")."""
    if backend not in BACKENDS:
        raise ValueError(
            f"backend must be one of {BACKENDS}, got {backend!r}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend


def use_pallas(backend: str) -> bool:
    return resolve_backend(backend) == "pallas"


def unique_sweep_widths(arms) -> Tuple[int, ...]:
    """Distinct positive speculation depths of an arm table, sorted.

    The adaptive spec_step (DESIGN.md §9) drafts once per depth returned
    here — each is one statically-shaped ``ngram_sweep`` baked into the SAME
    compiled step, because the sweep's continuation hash is a function of w.
    This is the dispatch layer's no-recompile contract for masking: the set
    of kernel instantiations one adaptive step contains is fixed by the arm
    TABLE (static), never by the arms slots happen to pick at runtime.
    w == 0 arms (plain greedy) need no sweep and contribute nothing.
    """
    return tuple(sorted({w for _, w in arms if w > 0}))


def default_interpret() -> bool:
    """Pallas kernels run in interpret mode off-TPU (tests force this by
    construction: CI has no TPU, so ``backend="pallas"`` == interpret)."""
    return jax.default_backend() != "tpu"


# ----------------------------------------------------------------------------
# buffer alignment (serving sizes its DecodeState through this)
# ----------------------------------------------------------------------------
def align_cache_len(n: int, block_s: int = 0) -> int:
    """Smallest cache length >= n that ``spec_attention_op`` never repads.

    A cache of S slots is streamed in blocks of ``min(block_s, S)``; padding
    happens iff S does not divide into whole blocks.  Below one block the
    kernel takes the cache as a single block, so only sublane alignment is
    applied there.  ``block_s=0`` means the kernel default.
    """
    bs = block_s or ops.DEFAULT_BLOCK_S
    if n >= bs:
        return -(-n // bs) * bs
    return -(-n // SUBLANE) * SUBLANE


# ----------------------------------------------------------------------------
# bifurcated verify attention
# ----------------------------------------------------------------------------
def pallas_verify_supported(cfg) -> bool:
    """Kernel-eligibility for a ModelConfig: the Pallas verify kernel
    implements the linear-cache, no-softcap contract; configs outside it
    (Gemma softcap, Mixtral sliding-window ring cache) keep the XLA path
    even under ``backend="pallas"``."""
    return (cfg.attn_logit_softcap is None
            and cfg.sliding_window is None)


def _static_mask(tail_mask) -> Optional[Tuple[Tuple[bool, ...], ...]]:
    """numpy (N, N) bool -> hashable tuple-of-tuples for the jitted ops.

    The tail mask is a compile-time tree-topology constant (DESIGN.md §11),
    so it belongs in the jit cache key: one kernel instantiation per
    topology, zero per-call operands.
    """
    if tail_mask is None:
        return None
    return tuple(map(tuple, np.asarray(tail_mask, bool).tolist()))


def verify_attention(q, k_cache, v_cache, k_tail, v_tail, cur_len, *,
                     w1: int, block_s: int = 0,
                     tail_mask=None) -> jnp.ndarray:
    """Pallas bifurcated verify attention in the engine layout.

    q: (B, K, W1, H, hd); caches (B, S, KV, hd); tails (B, K, W1, KV, hd);
    cur_len (B,).  Returns (B, K, W1, H, hd).

    ``tail_mask``: optional static (K*W1, K*W1) bool numpy array replacing
    the per-row causal tail mask — tree verification's ancestor-only
    visibility (DESIGN.md §11; K == 1 there, the tree is one "row").

    Masked-shape contract (adaptive arms, DESIGN.md §9): K/W1 are the
    compile-time maxima; a slot running a smaller (k, w) arm simply has its
    surplus rows/positions ignored downstream (attention is causal per row
    / ancestor-only per tree node, so the extra positions cannot influence
    the accepted prefix) — one compilation serves every arm.
    """
    bs = block_s if block_s else ops.DEFAULT_BLOCK_S
    return ops.spec_attention_op(q, k_cache, v_cache, k_tail, v_tail,
                                 cur_len, w1=w1, block_s=bs,
                                 interpret=default_interpret(),
                                 tail_mask=_static_mask(tail_mask))


def verify_attention_paged(q, k_pool, v_pool, page_table, k_tail, v_tail,
                           cur_len, *, w1: int, tail_mask=None) -> jnp.ndarray:
    """Pallas bifurcated verify attention over a paged KV pool.

    q: (B, K, W1, H, hd); pools (num_pages, page_size, KV, hd); page_table
    (B, pages_per_slot) int32 (-1 = unallocated); tails (B, K, W1, KV, hd);
    cur_len (B,); tail_mask as in ``verify_attention``.  Returns
    (B, K, W1, H, hd).  The kernel's cache-block grid walks the page table
    (one grid step per page), so page_size plays the role block_s has on
    the linear path.  The same masked-shape contract as
    ``verify_attention`` applies: K/W1 are arm-table maxima, one compile.
    """
    return ops.paged_spec_attention_op(q, k_pool, v_pool, page_table,
                                       k_tail, v_tail, cur_len, w1=w1,
                                       interpret=default_interpret(),
                                       tail_mask=_static_mask(tail_mask))


# ----------------------------------------------------------------------------
# context N-gram match/hash sweep
# ----------------------------------------------------------------------------
def ngram_sweep(buf: jnp.ndarray, query: jnp.ndarray, cur_len: jnp.ndarray,
                *, w: int, backend: str,
                block_l: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Backend-dispatched match/hash sweep over every context position.

    buf: (B, L) int32; query: (B, q); cur_len: (B,).
    Returns (match (B, L) int32, hash (B, L) uint32) where
      match[b, i] = all(buf[b, i:i+q] == query[b]) and i + q + w <= cur_len
      hash[b, i]  = hashing.hash_rows(buf[b, i+q : i+q+w])

    Both backends produce bit-identical integers (property the scoring
    stage in core/drafters.py relies on), so drafts cannot depend on the
    backend.

    Mesh seam (DESIGN.md §10): like ``attn_verify``, an installed
    activation sharder pins this to the XLA path — the Pallas sweep is a
    single-device ``pallas_call`` that the SPMD partitioner cannot split,
    so dispatching it over a data-sharded ``buf`` inside the sharded
    spec_step would fail to lower (or gather the buffer every step).
    """
    bl = block_l if block_l else ops.DEFAULT_BLOCK_L
    from ..distributed import act_sharding
    if use_pallas(backend) and not act_sharding.installed():
        return ops.ngram_match_op(buf, query, cur_len, w=w, block_l=bl,
                                  interpret=default_interpret())
    B, L = buf.shape
    q = query.shape[1]
    pad = jnp.full((B, q + w), -1, jnp.int32)
    bufp = jnp.concatenate([buf.astype(jnp.int32), pad], axis=1)
    fn = lambda b, qq, c: ref.ngram_match_ref(b, qq, c[None], w=w)
    return jax.vmap(fn)(bufp, query.astype(jnp.int32),
                        cur_len.astype(jnp.int32))
