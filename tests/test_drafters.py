"""Drafters: context N-gram vs a brute-force oracle; table builders."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.drafters import (bigram_draft, context_ngram_draft,
                                 mixed_draft, unigram_draft)
from repro.core.ngram_tables import (NGramTables, chain_from_argmax,
                                     tables_from_counts)


def brute_force_context(buf, cur_len, q, k, w):
    """The paper's Appendix B.2 semantics, in plain Python."""
    buf = list(buf[:cur_len])
    query = buf[cur_len - q:cur_len]
    matches = {}
    for i in range(0, cur_len - q - w + 1):
        if buf[i:i + q] == query:
            cont = tuple(buf[i + q:i + q + w])
            cnt, _ = matches.get(cont, (0, -1))
            matches.get(cont)
            matches[cont] = (cnt + 1, i)
    ranked = sorted(matches.items(),
                    key=lambda kv: (kv[1][0], kv[1][1]), reverse=True)
    return [list(c) for c, _ in ranked[:k]]


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("q,w", [(1, 3), (2, 2), (3, 4)])
def test_context_ngram_matches_bruteforce(seed, q, w):
    rng = np.random.default_rng(seed)
    L, cur, k = 64, 50, 4
    buf = rng.integers(0, 5, size=(1, L)).astype(np.int32)  # small alphabet
    d, v = context_ngram_draft(jnp.asarray(buf), jnp.asarray([cur]), q, k, w)
    got = [list(np.asarray(d[0, i])) for i in range(k) if bool(v[0, i])]
    want = brute_force_context(buf[0], cur, q, k, w)
    assert len(got) == len(want)
    # counts can tie across different continuations with equal recency rank:
    # compare as ordered multisets of (count-validated) drafts
    assert got == want


def test_context_ngram_empty_context():
    buf = jnp.zeros((1, 32), jnp.int32)
    d, v = context_ngram_draft(buf, jnp.asarray([0]), 1, 4, 3)
    assert not bool(v.any())


def test_bigram_and_unigram_drafts():
    counts = jnp.asarray(np.random.default_rng(0).integers(
        0, 10, size=(13, 13)).astype(np.float32))
    t = tables_from_counts(counts, k_max=5, w_max=6)
    d, v = bigram_draft(t, jnp.asarray([3, 7]), k=4, w=5)
    assert d.shape == (2, 4, 5) and bool(v.all())
    # first column is the top-k of row x; the chain follows argmax
    np.testing.assert_array_equal(np.asarray(d[0, :, 0]),
                                  np.asarray(t.bigram_topk[3, :4]))
    am = np.asarray(t.bigram_topk[:, 0])
    for i in range(4):
        row = np.asarray(d[0, i])
        for j in range(1, 5):
            assert row[j] == am[row[j - 1]]
    du, vu = unigram_draft(t, batch=2, k=3, w=2)
    assert du.shape == (2, 3, 2) and bool(vu.all())
    np.testing.assert_array_equal(np.asarray(du[0, :, 0]),
                                  np.asarray(t.unigram_topk[:3]))


def test_chain_from_argmax():
    am = jnp.asarray([1, 2, 0], jnp.int32)
    chain = chain_from_argmax(am, 4)
    np.testing.assert_array_equal(np.asarray(chain[0]), [1, 2, 0, 1])


def test_mixed_allocation():
    """Context drafts occupy the first rows; bigram fills the remainder."""
    rng = np.random.default_rng(0)
    counts = jnp.asarray(rng.integers(0, 10, size=(7, 7)).astype(np.float32))
    t = tables_from_counts(counts, k_max=8, w_max=8)
    # buffer with an obvious repeated pattern "1 2 3"
    buf = jnp.asarray([[1, 2, 3, 1, 2, 3, 1, 2, 3, 1] + [0] * 22], jnp.int32)
    cur = jnp.asarray([10], jnp.int32)
    k, w = 4, 2
    d, v, n_ctx = mixed_draft(t, buf, cur, buf[:, 9], q=1, k=k, w=w)
    assert bool(v.all())
    assert int(n_ctx[0]) >= 1
    # the first row must be the context continuation of "... 1" -> "2 3"
    np.testing.assert_array_equal(np.asarray(d[0, 0]), [2, 3])
    # remaining rows are extended-bigram drafts for last token 1
    bg, _ = bigram_draft(t, buf[:, 9], k=k, w=w)
    nc = int(n_ctx[0])
    np.testing.assert_array_equal(np.asarray(d[0, nc:]),
                                  np.asarray(bg[0, :k - nc]))
