"""Direct coverage for serving/scheduler.py: bucketing, padding, FIFO
fairness across next_batch calls, the continuous-batching slot map, and the
cache slot-reset/insert helpers (no cross-request leakage)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.tokenizer import ByteTokenizer
from repro.models import cache as C
from repro.models import model as M
from repro.serving.scheduler import (Request, Scheduler, SlotMap,
                                     fit_bucket)


# ---------------------------------------------------------------------------
# bucketing / padding
# ---------------------------------------------------------------------------
def test_bucket_boundaries():
    s = Scheduler(buckets=(32, 64, 128))
    assert s._bucket(1) == 32
    assert s._bucket(32) == 32          # boundary is inclusive
    assert s._bucket(33) == 64
    assert s._bucket(64) == 64
    assert s._bucket(128) == 128
    assert s._bucket(129) == 128        # overflow clamps to largest bucket
    # buckets are sorted regardless of constructor order
    assert Scheduler(buckets=(128, 32, 64)).buckets == (32, 64, 128)


def test_fit_bucket_and_queue_sizing():
    assert fit_bucket(5) == 32 and fit_bucket(33) == 64
    assert fit_bucket(9999) == 512                  # clamps to largest
    assert fit_bucket(40, (128, 32, 64)) == 64      # sorts its input
    s = Scheduler(buckets=(16, 32, 64))
    assert s.max_queued_bucket() is None
    s.submit(Request(prompt="a" * 5))
    assert s.max_queued_bucket() == 16
    s.submit(Request(prompt="b" * 30))
    assert s.max_queued_bucket() == 32


def test_left_padding_places_last_token_at_bucket_end():
    s = Scheduler(buckets=(16,))
    tok = ByteTokenizer()
    ids = tok.encode("hello")            # bos + 5 bytes = 6 ids
    padded = s.pad_to_bucket(ids)
    assert padded.shape == (16,)
    assert list(padded[-len(ids):]) == ids               # suffix = prompt
    assert (padded[:16 - len(ids)] == tok.bos_id).all()  # prefix = BOS fill
    # over-long prompts keep the most recent bucket-many ids
    long_ids = tok.encode("x" * 40)
    padded = s.pad_to_bucket(long_ids)
    assert list(padded) == long_ids[-16:]


def test_batches_never_drop_or_duplicate_requests():
    s = Scheduler(max_batch=3, buckets=(16, 32))
    reqs = [Request(prompt="a" * (3 + 7 * (i % 4)), max_new_tokens=8)
            for i in range(11)]
    for r in reqs:
        s.submit(r)
    seen = []
    while (b := s.next_batch()) is not None:
        assert len(b.requests) <= 3
        assert b.tokens.shape[0] == len(b.requests)
        seen.extend(r.request_id for r in b.requests)
    assert s.pending() == 0
    assert sorted(seen) == sorted(r.request_id for r in reqs)
    assert len(seen) == len(set(seen))


def test_fifo_within_group():
    s = Scheduler(max_batch=2, buckets=(16,))
    reqs = [Request(prompt=f"req {i}", max_new_tokens=8) for i in range(5)]
    for r in reqs:
        s.submit(r)
    order = []
    while (b := s.next_batch()) is not None:
        order.extend(r.request_id for r in b.requests)
    assert order == [r.request_id for r in reqs]   # submission order


def test_pop_next_fifo():
    s = Scheduler(buckets=(16,))
    reqs = [Request(prompt=f"req {i}") for i in range(3)]
    for r in reqs:
        s.submit(r)
    popped = []
    while (p := s.pop_next()) is not None:
        req, toks = p
        assert toks.shape == (16,)
        popped.append(req.request_id)
    assert popped == [r.request_id for r in reqs]
    assert s.pending() == 0


# ---------------------------------------------------------------------------
# slot map
# ---------------------------------------------------------------------------
def test_slot_map_assign_release_reuse():
    sm = SlotMap(2)
    assert sm.free_slots() == [0, 1] and len(sm) == 0
    r1, r2, r3 = (Request(prompt=p) for p in "abc")
    sm.assign(0, r1)
    sm.assign(1, r2)
    assert sm.free_slots() == [] and len(sm) == 2
    assert sm.get(0) is r1
    with pytest.raises(ValueError):
        sm.assign(0, r3)                 # double-assign is a bug
    assert sm.release(0) is r1
    with pytest.raises(ValueError):
        sm.release(0)                    # double-release too
    sm.assign(0, r3)                     # freed slot is reusable
    assert {i for i, _ in sm.occupied()} == {0, 1}


# ---------------------------------------------------------------------------
# cache slot reset / insert (continuous-batching admission primitive)
# ---------------------------------------------------------------------------
def _states_equal(a, b, slot_a, slot_b):
    """Compare one batch row of two cache states leaf-by-leaf."""
    for gid, g in a["groups"].items():
        la = jax.tree_util.tree_leaves(g)
        lb = jax.tree_util.tree_leaves(b["groups"][gid])
        for x, y in zip(la, lb):
            if not np.array_equal(np.asarray(x[:, slot_a]),
                                  np.asarray(y[:, slot_b])):
                return False
    return bool(a["cur_len"][slot_a] == b["cur_len"][slot_b])


@pytest.mark.parametrize("arch", ["dense", "hybrid"])
def test_cache_slot_reset_and_insert(arch, tiny_dense_cfg, tiny_hybrid_cfg):
    cfg = tiny_dense_cfg if arch == "dense" else tiny_hybrid_cfg
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    L = 24
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    state = M.init_state(cfg, 2, L)
    _, state = M.prefill(params, cfg, state, tokens=prompt)

    # reset row 1: it must equal a freshly-initialised state (no residue
    # from the previous request), row 0 must be untouched
    state_r = C.reset_slot(cfg, state, jnp.int32(1))
    fresh = M.init_state(cfg, 2, L)
    assert _states_equal(state_r, fresh, 1, 1)
    assert int(state_r["cur_len"][1]) == 0
    assert _states_equal(state_r, state, 0, 0)

    # insert: prefilling row 1's prompt alone and inserting it into slot 1
    # reproduces the batched prefill bit-for-bit (so admission into a reused
    # slot serves request N+1 exactly as if it had a private cache)
    row = M.init_state(cfg, 1, L)
    _, row = M.prefill(params, cfg, row, tokens=prompt[1:2])
    state_i = C.insert_slot(state_r, row, jnp.int32(1))
    assert _states_equal(state_i, state, 1, 1)
    assert int(state_i["cur_len"][1]) == int(state["cur_len"][1])


def test_insert_slot_rejects_shape_mismatch(tiny_dense_cfg):
    cfg = tiny_dense_cfg
    state = M.init_state(cfg, 2, 24)
    row = M.init_state(cfg, 1, 32)       # different buffer length
    with pytest.raises(ValueError):
        C.insert_slot(state, row, jnp.int32(0))
