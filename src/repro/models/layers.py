"""Shared layers: norms, MLP variants, embeddings, init helpers.

Parameters are plain nested dicts of jnp arrays (no flax dependency).  Every
layer is a pair of functions ``init_*(rng, cfg, ...) -> params`` and
``apply_*(params, x, ...) -> y`` so stacks of layers can be ``jax.vmap``-ed
into scanned super-blocks.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .config import GEGLU, GELU, RELU2, SWIGLU, ModelConfig

Params = Dict[str, jnp.ndarray]


# ----------------------------------------------------------------------------
# init helpers
# ----------------------------------------------------------------------------
def dense_init(rng, shape, dtype, scale: float = 1.0) -> jnp.ndarray:
    """Truncated-normal fan-in init (matches common LLM inits)."""
    fan_in = shape[0]
    std = scale / (fan_in ** 0.5)
    return (std * jax.random.truncated_normal(rng, -2.0, 2.0, shape,
                                              jnp.float32)).astype(dtype)


def embed_init(rng, shape, dtype) -> jnp.ndarray:
    return (0.02 * jax.random.normal(rng, shape, jnp.float32)).astype(dtype)


# ----------------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------------
def init_norm(cfg: ModelConfig) -> Params:
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((cfg.d_model,), cfg.param_dtype)}
    return {"scale": jnp.ones((cfg.d_model,), cfg.param_dtype),
            "bias": jnp.zeros((cfg.d_model,), cfg.param_dtype)}


def apply_norm(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps)
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------------
def init_mlp(rng, cfg: ModelConfig, kind: str, d_ff: int = 0) -> Params:
    d_ff = d_ff or cfg.d_ff
    d = cfg.d_model
    dt = cfg.param_dtype
    ks = jax.random.split(rng, 3)
    if kind in (SWIGLU, GEGLU):
        return {"w_gate": dense_init(ks[0], (d, d_ff), dt),
                "w_up": dense_init(ks[1], (d, d_ff), dt),
                "w_down": dense_init(ks[2], (d_ff, d), dt)}
    if kind in (RELU2, GELU):
        return {"w_up": dense_init(ks[0], (d, d_ff), dt),
                "w_down": dense_init(ks[1], (d_ff, d), dt)}
    raise ValueError(kind)


def _gelu(x, approx: bool):
    return jax.nn.gelu(x, approximate=approx)


def apply_mlp(params: Params, x: jnp.ndarray, cfg: ModelConfig,
              kind: str) -> jnp.ndarray:
    x = x.astype(cfg.compute_dtype)
    if kind == SWIGLU:
        g = jax.nn.silu(x @ params["w_gate"].astype(cfg.compute_dtype))
        u = x @ params["w_up"].astype(cfg.compute_dtype)
        return (g * u) @ params["w_down"].astype(cfg.compute_dtype)
    if kind == GEGLU:
        g = _gelu(x @ params["w_gate"].astype(cfg.compute_dtype), cfg.gelu_approx)
        u = x @ params["w_up"].astype(cfg.compute_dtype)
        return (g * u) @ params["w_down"].astype(cfg.compute_dtype)
    if kind == RELU2:  # squared ReLU (Nemotron-4)
        h = jnp.square(jax.nn.relu(x @ params["w_up"].astype(cfg.compute_dtype)))
        return h @ params["w_down"].astype(cfg.compute_dtype)
    if kind == GELU:
        h = _gelu(x @ params["w_up"].astype(cfg.compute_dtype), cfg.gelu_approx)
        return h @ params["w_down"].astype(cfg.compute_dtype)
    raise ValueError(kind)


# ----------------------------------------------------------------------------
# embeddings / head
# ----------------------------------------------------------------------------
def init_embed(rng, cfg: ModelConfig) -> Params:
    ks = jax.random.split(rng, 2)
    p = {"embedding": embed_init(ks[0], (cfg.vocab_size, cfg.d_model),
                                 cfg.param_dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size),
                                  cfg.param_dtype)
    return p


def embed_tokens(params: Params, tokens: jnp.ndarray,
                 cfg: ModelConfig) -> jnp.ndarray:
    x = jnp.take(params["embedding"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.scale_embed:  # Gemma
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype)
    return x


def lm_logits(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        w = params["embedding"].astype(cfg.compute_dtype).T
    else:
        w = params["lm_head"].astype(cfg.compute_dtype)
    return (x.astype(cfg.compute_dtype) @ w).astype(jnp.float32)
