"""Training step + loop: next-token cross-entropy (+ MoE aux loss), remat,
and the jit/pjit train_step factory used by both the CPU quickstart and the
multi-pod dry-run.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import model as M
from ..models.config import ModelConfig
from .optimizer import AdamWConfig, adamw_update, init_opt_state


LOSS_CHUNK = 512        # time-chunk for the big-vocab cross entropy
CHUNKED_LOSS_MIN_T = 2048


def _ce_from_hidden(params, cfg, hidden, labels):
    """Cross entropy from final hidden states, chunked over time so the
    (B, T, vocab) f32 logits never materialise for 256k-vocab configs.
    Each chunk is checkpointed: backward recomputes its logits."""
    from ..models.layers import lm_logits
    B, T, d = hidden.shape
    if T < CHUNKED_LOSS_MIN_T or T % LOSS_CHUNK != 0:
        logits = lm_logits(params["embed"], hidden, cfg)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return nll.mean()
    nc = T // LOSS_CHUNK
    h = hidden.reshape(B, nc, LOSS_CHUNK, d).swapaxes(0, 1)
    lbl = labels.reshape(B, nc, LOSS_CHUNK).swapaxes(0, 1)

    from ..distributed import act_sharding

    @jax.checkpoint
    def chunk_nll(carry, xs):
        h_c, l_c = xs
        logits = lm_logits(params["embed"], h_c, cfg)
        logits = act_sharding.constrain(logits, "logits")
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, l_c[..., None], axis=-1)[..., 0]
        return carry + nll.sum(), None

    from ..models.runtime_flags import UNROLL_FOR_ANALYSIS
    if UNROLL_FOR_ANALYSIS:
        total = jnp.zeros((), jnp.float32)
        for i in range(nc):
            total, _ = chunk_nll(total, (h[i], lbl[i]))
    else:
        total, _ = jax.lax.scan(chunk_nll, jnp.zeros((), jnp.float32),
                                (h, lbl))
    return total / (B * T)


def lm_loss(params, cfg: ModelConfig, batch: jnp.ndarray,
            remat: bool = False) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """batch: (B, T+1) int32 -> (loss, metrics)."""
    inputs, labels = batch[:, :-1], batch[:, 1:]
    hidden, aux = M.forward_hidden(params, cfg, tokens=inputs, remat=remat)
    loss = _ce_from_hidden(params, cfg, hidden, labels)
    total = loss + cfg.router_aux_loss_coef * aux
    return total, {"loss": loss, "aux_loss": aux,
                   "ppl": jnp.exp(jnp.clip(loss, 0, 20.0))}


def encoder_loss(params, cfg: ModelConfig, embeds: jnp.ndarray,
                 targets: jnp.ndarray, remat: bool = False):
    """Embedding-input losses: HuBERT-style per-frame unit prediction, and
    the VLM-backbone variant (precomputed multimodal embeddings -> token
    targets).  Chunked CE for the big-vocab VLM case."""
    hidden, aux = M.forward_hidden(params, cfg, embeds=embeds, remat=remat)
    loss = _ce_from_hidden(params, cfg, hidden, targets)
    loss = loss + cfg.router_aux_loss_coef * aux
    return loss, {"loss": loss, "aux_loss": aux,
                  "ppl": jnp.exp(jnp.clip(loss, 0, 20.0))}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    remat: bool = True) -> Callable:
    """Returns train_step(train_state, batch) -> (train_state, metrics).

    train_state = {"params": ..., "opt": ...}.  The same function is jit'd
    on CPU for the quickstart and pjit'd (with shardings) by the launcher.
    """
    def train_step(train_state, batch):
        if cfg.embedding_inputs:
            embeds, targets = batch
            grad_fn = jax.value_and_grad(
                lambda p: encoder_loss(p, cfg, embeds, targets, remat),
                has_aux=True)
        else:
            grad_fn = jax.value_and_grad(
                lambda p: lm_loss(p, cfg, batch, remat), has_aux=True)
        (loss, metrics), grads = grad_fn(train_state["params"])
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, train_state["params"], grads, train_state["opt"])
        metrics = {**metrics, **opt_metrics, "total_loss": loss}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_train_state(rng, cfg: ModelConfig) -> Dict[str, Any]:
    params = M.init_params(rng, cfg)
    return {"params": params, "opt": init_opt_state(params)}
