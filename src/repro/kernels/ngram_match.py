"""Pallas TPU kernel: context N-gram matching (paper §4.2, Appendix B.2).

The O(L·(q+w)) part of the context drafter — comparing the last q tokens
against every context position and hashing every w-token continuation — is
a perfect VPU job: the token buffer is tiny (500k tokens = 2 MB int32, far
under VMEM), so the whole buffer is kept resident in VMEM while the grid
walks output blocks of positions.  The (count, recency) scoring and top-k
stay in plain XLA (sort-based; O(L log L) but bandwidth-trivial).

Outputs per position i:
  match[i] = all(buf[i:i+q] == query) and i + q + w <= cur_len
  hash[i]  = polynomial uint32 hash of buf[i+q : i+q+w]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .hashing import hash_step

DEFAULT_BLOCK_L = 1024


def _kernel(cur_len_ref, buf_ref, query_ref, match_ref, hash_ref, *,
            q: int, w: int, block_l: int):
    i = pl.program_id(0)
    base = i * block_l
    pos = base + jax.lax.broadcasted_iota(jnp.int32, (block_l,), 0)

    match = jnp.ones((block_l,), jnp.bool_)
    for j in range(q):
        tok = pl.load(buf_ref, (pl.ds(base + j, block_l),))
        match = match & (tok == query_ref[j])
    # windows that would run past the committed context are invalid
    # (cur_len <= true L, so this also masks the padded region)
    match = match & (pos + q + w <= cur_len_ref[0])

    h = jnp.zeros((block_l,), jnp.uint32)
    for j in range(w):
        tok = pl.load(buf_ref, (pl.ds(base + q + j, block_l),))
        h = hash_step(h, tok)
    match_ref[...] = match.astype(jnp.int32)
    hash_ref[...] = h


def ngram_match_call(buf: jnp.ndarray, query: jnp.ndarray,
                     cur_len: jnp.ndarray, *, w: int,
                     block_l: int = DEFAULT_BLOCK_L,
                     interpret: bool = False):
    """buf: (L + q + w,) int32, PADDED by the ops wrapper so every window
    load is in bounds (single sequence; vmap over batch in ops.py).
    query: (q,) int32; cur_len: (1,) int32.
    Returns (match (L,) int32, hash (L,) uint32) for the first L positions.
    """
    q = query.shape[0]
    L = buf.shape[0] - q - w
    assert L % block_l == 0, (L, block_l)
    kernel = functools.partial(_kernel, q=q, w=w, block_l=block_l)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(L // block_l,),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),   # whole buf in VMEM
                pl.BlockSpec(memory_space=pltpu.ANY),   # query
            ],
            out_specs=[
                pl.BlockSpec((block_l,), lambda i, c: (i,)),
                pl.BlockSpec((block_l,), lambda i, c: (i,)),
            ],
        ),
        out_shape=[jax.ShapeDtypeStruct((L,), jnp.int32),
                   jax.ShapeDtypeStruct((L,), jnp.uint32)],
        interpret=interpret,
    )(cur_len, buf, query)
