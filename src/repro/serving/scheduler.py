"""Request scheduling: queueing, length-bucketing, batch formation.

The engine's jitted generation requires equal prompt lengths per batch (one
prefill shape per bucket keeps recompilation bounded); the scheduler pads
prompts up to the bucket boundary and groups by (bucket, max_new_tokens).
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..data.tokenizer import ByteTokenizer

_counter = itertools.count()


@dataclasses.dataclass
class Request:
    prompt: str
    max_new_tokens: int = 64
    request_id: int = dataclasses.field(default_factory=lambda: next(_counter))
    # filled on completion:
    output: Optional[str] = None
    stats: Optional[dict] = None


@dataclasses.dataclass
class Batch:
    requests: List[Request]
    tokens: np.ndarray           # (B, P) int32, right-padded to bucket
    max_new_tokens: int


class Scheduler:
    """FIFO with length bucketing."""

    def __init__(self, max_batch: int = 8,
                 buckets: Tuple[int, ...] = (32, 64, 128, 256, 512)):
        self.max_batch = max_batch
        self.buckets = tuple(sorted(buckets))
        self.tok = ByteTokenizer()
        self._queue: List[Tuple[Request, List[int]]] = []

    def submit(self, req: Request) -> int:
        ids = self.tok.encode(req.prompt)
        self._queue.append((req, ids))
        return req.request_id

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def next_batch(self) -> Optional[Batch]:
        if not self._queue:
            return None
        groups: Dict[Tuple[int, int], List[Tuple[Request, List[int]]]] = \
            defaultdict(list)
        for req, ids in self._queue:
            key = (self._bucket(len(ids)), req.max_new_tokens)
            groups[key].append((req, ids))
        # take the largest group (best batching efficiency)
        key = max(groups, key=lambda k: len(groups[k]))
        chosen = groups[key][:self.max_batch]
        chosen_ids = {id(r) for r, _ in chosen}
        self._queue = [(r, i) for r, i in self._queue
                       if id(r) not in chosen_ids]
        bucket, mnt = key
        # LEFT-pad so that the last prompt token sits at position bucket-1:
        # the jitted engine prefills a uniform length and starts generating
        # from the final position of every row.  (Per-row pad masking inside
        # recurrent prefill is future work; BOS-padding keeps the shift tiny.)
        toks = np.full((len(chosen), bucket), self.tok.bos_id, np.int32)
        for i, (_, ids) in enumerate(chosen):
            ids = ids[-bucket:]
            toks[i, -len(ids):] = ids
        return Batch([r for r, _ in chosen], toks, mnt)

    def pending(self) -> int:
        return len(self._queue)
