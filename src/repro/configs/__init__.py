"""Architecture registry: the 10 assigned architectures + the paper's own.

``get_config(arch)`` / ``get_smoke_config(arch)`` are the ``--arch <id>``
entry points used by the launcher, dry-run and benchmarks.
``long_context_variant`` applies the sliding-window KV-cache variant that
makes `long_500k` runnable on full-attention dense archs (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from ..models.config import ModelConfig
from . import (deepseek_moe_16b, gemma_2b, glm4_9b, hubert_xlarge,
               jamba_1_5_large_398b, mistral_7b, mixtral_8x7b,
               nemotron_4_340b, qwen2_vl_72b, stablelm_1_6b, xlstm_125m)

_MODULES = {
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
    "xlstm-125m": xlstm_125m,
    "qwen2-vl-72b": qwen2_vl_72b,
    "stablelm-1.6b": stablelm_1_6b,
    "gemma-2b": gemma_2b,
    "hubert-xlarge": hubert_xlarge,
    "mixtral-8x7b": mixtral_8x7b,
    "nemotron-4-340b": nemotron_4_340b,
    "glm4-9b": glm4_9b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "mistral-7b": mistral_7b,            # the paper's own model
}

ASSIGNED_ARCHS: List[str] = [a for a in _MODULES if a != "mistral-7b"]
ALL_ARCHS: List[str] = list(_MODULES)

LONG_CONTEXT_WINDOW = 8192


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].smoke_config()


def long_context_variant(cfg: ModelConfig) -> ModelConfig:
    """Sliding-window variant for long_500k decode on full-attention dense
    archs: the KV cache becomes a ring of LONG_CONTEXT_WINDOW positions.
    SSM/hybrid archs and natively-SWA archs are returned unchanged."""
    has_attn = any(b.mixer == "attn"
                   for b in (tuple(cfg.prefix_blocks)
                             + tuple(cfg.block_pattern)))
    if not has_attn or cfg.sliding_window is not None:
        return cfg
    return dataclasses.replace(
        cfg, name=cfg.name + "+swa", sliding_window=LONG_CONTEXT_WINDOW)


def supports_decode(cfg: ModelConfig) -> bool:
    return not cfg.encoder_only


def supports_long_decode(cfg: ModelConfig) -> bool:
    """Sub-quadratic decode at 524k: SSM/hybrid natively; attention archs via
    sliding window (native or the +swa variant)."""
    if cfg.encoder_only:
        return False
    return True  # after long_context_variant every decodable arch qualifies
