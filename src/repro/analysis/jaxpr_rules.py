"""Level-1 (jaxpr/lowering) analyzers over the real engine entry points.

Each rule traces the PRODUCTION step/admit/release bodies (the exact
functions ``spec_step``/``admit_slot``/``release_slot`` and ``generate``'s
while-body jit) on abstract states from ``registry.py`` — no execution, no
model weights beyond the tiny registry params.

Rules (each with the PR whose bug class it mechanizes):

  - ``donation``        — every donated DecodeState leaf is actually
    aliased into an output in the lowered module, and no two distinct
    state leaves share one device buffer (PR 1: cache.init_state's SLSTM
    shared-zeros buffer made donation alias two logical leaves).
  - ``sharding-coverage`` — every DecodeState leaf resolves under
    ``decode_state_pspec(strict=True)`` on every registry mesh with zero
    ShardingFallbackWarnings (PR 7 added rng_key/temperature/top_p leaves;
    nothing forced a pspec rule for them until a human noticed).
  - ``trace-signature`` — the state's abstract signature is a FIXED POINT
    of step/admit/release (out avals == in avals, weak types included), so
    the serving loop compiles each body exactly once per shape.  Replaces
    the per-PR compile-count spies with one reusable checker.
  - ``host-sync``       — no callback/infeed/outfeed primitive inside the
    jitted bodies (the AST half of this rule — the serving-loop sync scan
    — lives in ast_rules.serving_sync_findings).
"""
from __future__ import annotations

import re
import warnings
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core.spec_engine import _admit_body, _release_body, _step_body
from ..distributed import sharding as shd
from ..models import cache as C
from . import registry
from .findings import Finding

# ---------------------------------------------------------------------------
# donation soundness
# ---------------------------------------------------------------------------
_ALIAS_ATTR = "tf.aliasing_output"


def donation_findings(fn: Callable, args: Sequence, donated_tree,
                      label: str) -> List[Finding]:
    """Lower ``jit(fn, donate_argnums=0)`` and verify every leaf of the
    donated first argument is aliased into an output.

    JAX matches donated inputs to outputs by aval at lowering time: a
    donated leaf whose shape/dtype matches no output is silently copied
    (and warned about) instead of updated in place — for the serving state
    that means a full KV-cache copy per step.  The lowered module carries
    one ``tf.aliasing_output`` attribute per aliased parameter, so the
    check is: #aliased == #donated leaves, and no donation warning fired.
    """
    n_donated = len(jax.tree_util.tree_leaves(donated_tree))
    findings: List[Finding] = []
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        lowered = jax.jit(fn, donate_argnums=0).lower(*args)
    for w in caught:
        if "donated" in str(w.message).lower():
            findings.append(Finding(
                rule="donation", file=label, line=0,
                message=f"unusable donation: {str(w.message).splitlines()[0]}",
                hint="make the donated leaf's aval match an output leaf "
                     "(or stop donating it)",
                context=f"{label}::donation-warning"))
    n_aliased = lowered.as_text().count(_ALIAS_ATTR)
    if n_aliased < n_donated and not findings:
        findings.append(Finding(
            rule="donation", file=label, line=0,
            message=f"only {n_aliased}/{n_donated} donated leaves are "
                    f"aliased into outputs in the lowered module",
            hint="every DecodeState leaf must round-trip through the body "
                 "with an unchanged aval so XLA can update it in place",
            context=f"{label}::alias-count"))
    return findings


def shared_buffer_findings(tree, label: str) -> List[Finding]:
    """No two distinct pytree leaves may share one device buffer: donating
    such a state aliases BOTH logical leaves onto one output buffer and
    the second write corrupts the first (the PR-1 init_state bug, where
    SLSTM groups reused a single zeros array)."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    seen = {}
    findings = []
    for path, leaf in flat:
        if not hasattr(leaf, "unsafe_buffer_pointer"):
            continue
        ptr = leaf.unsafe_buffer_pointer()
        name = "/".join(shd._path_names(path))
        if ptr in seen:
            findings.append(Finding(
                rule="donation", file=label, line=0,
                message=f"leaves {seen[ptr]!r} and {name!r} share one "
                        f"device buffer — donation would alias both onto "
                        f"the same output",
                hint="construct each leaf with its own buffer (no shared "
                     "zeros/broadcast views) — cf. cache.init_state",
                context=f"{label}::shared-buffer::{name}"))
        else:
            seen[ptr] = name
    return findings


# ---------------------------------------------------------------------------
# entry-point plumbing shared by the per-case checks
# ---------------------------------------------------------------------------
def _entry_points(built: registry.BuiltCase):
    """(label, fn(state, ...), extra arg structs) for the three bodies."""
    params, cfg, spec = built.params, built.cfg, built.spec
    tables = built.tables
    scal = jax.ShapeDtypeStruct((), jnp.int32)
    scalf = jax.ShapeDtypeStruct((), jnp.float32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    step = lambda s: _step_body(params, cfg, spec, tables, s)
    admit = lambda s, slot, prompt, mnt, eos, t, tp, k: _admit_body(
        params, cfg, s, slot, prompt, mnt, eos, t, tp, k)
    release = lambda s, slot: _release_body(s, slot)
    return (
        ("spec_step", step, ()),
        ("admit_slot", admit,
         (scal, built.prompt_struct(), scal, scal, scalf, scalf, key)),
        ("release_slot", release, (scal,)),
    )


def check_donation(built: registry.BuiltCase) -> List[Finding]:
    findings = shared_buffer_findings(
        built.state, f"<case:{built.name}/empty_decode_state>")
    struct = built.state_struct
    for name, fn, extra in _entry_points(built):
        findings += donation_findings(fn, (struct,) + tuple(extra), struct,
                                      f"<case:{built.name}/{name}>")
    return findings


# ---------------------------------------------------------------------------
# sharding coverage
# ---------------------------------------------------------------------------
def check_sharding_coverage(
        built: registry.BuiltCase,
        meshes: Sequence[registry.MeshShape] = registry.MESHES
) -> List[Finding]:
    findings: List[Finding] = []
    paged = C.is_paged(built.state.model)
    flat = jax.tree_util.tree_flatten_with_path(built.state)[0]
    shd.reset_fallback_warnings()
    for mesh in meshes:
        label = f"<case:{built.name}/mesh:{mesh.name}>"
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            for path, leaf in flat:
                name = "/".join(shd._path_names(path))
                try:
                    shd.decode_state_pspec(mesh, path, leaf, paged=paged,
                                           strict=True)
                except KeyError as e:
                    findings.append(Finding(
                        rule="sharding-coverage", file=label, line=0,
                        message=f"DecodeState leaf {name!r} has no "
                                f"decode_state_pspec rule: {e.args[0]}",
                        hint="add the leaf to distributed/sharding.py's "
                             "DECODE_STATE_LEAF_RULES (and a pspec branch "
                             "if it needs more than slot-row sharding)",
                        context=f"sharding::{name}"))
        for w in caught:
            if issubclass(w.category, shd.ShardingFallbackWarning):
                findings.append(Finding(
                    rule="sharding-coverage", file=label, line=0,
                    message="replication fallback during state resolution: "
                            + str(w.message).splitlines()[0],
                    hint="registry dims are sized to divide every registry "
                         "mesh — a fallback here means a new leaf hit the "
                         "loud resolve_axis chain; probe with warn=False "
                         "or add a real rule",
                    context=f"sharding-fallback::{mesh.name}"))
    shd.reset_fallback_warnings()
    return findings


# ---------------------------------------------------------------------------
# trace-signature stability
# ---------------------------------------------------------------------------
def _aval_tuple(x):
    return (tuple(x.shape), jnp.dtype(x.dtype).name,
            bool(getattr(x, "weak_type", False)))


def signature_findings(fn: Callable, in_struct, label: str,
                       extra_args: Sequence = ()) -> List[Finding]:
    """The state signature must be a FIXED POINT of ``fn``: identical tree
    structure and per-leaf (shape, dtype, weak_type) in and out.  Any
    drift (an upcast stat, a weak-type scalar, a forgotten new leaf in a
    reset path) makes the serving loop retrace/recompile on every
    iteration — the class of bug the ad-hoc compile-count spies caught
    one instance at a time."""
    try:
        out = jax.eval_shape(fn, in_struct, *extra_args)
    except Exception as e:  # a body that fails to trace is its own finding
        return [Finding(
            rule="trace-signature", file=label, line=0,
            message=f"entry point failed to trace abstractly: {e!r:.200}",
            hint="the analyzer traces the real body on registry shapes; "
                 "fix the trace error or extend the registry",
            context=f"{label}::trace-error")]
    findings: List[Finding] = []
    in_paths = {"/".join(shd._path_names(p)): l for p, l in
                jax.tree_util.tree_flatten_with_path(in_struct)[0]}
    out_paths = {"/".join(shd._path_names(p)): l for p, l in
                 jax.tree_util.tree_flatten_with_path(out)[0]}
    for name in sorted(set(in_paths) | set(out_paths)):
        if name not in in_paths or name not in out_paths:
            which = "output" if name not in in_paths else "input"
            findings.append(Finding(
                rule="trace-signature", file=label, line=0,
                message=f"state leaf {name!r} exists only in the {which} "
                        f"signature — the loop's state tree changes shape "
                        f"across calls",
                hint="thread the leaf through every body (step AND the "
                     "admit/release resets)",
                context=f"signature::{name}::structure"))
            continue
        a, b = _aval_tuple(in_paths[name]), _aval_tuple(out_paths[name])
        if a != b:
            findings.append(Finding(
                rule="trace-signature", file=label, line=0,
                message=f"state leaf {name!r} signature drifts across the "
                        f"call: in {a} vs out {b} — every loop iteration "
                        f"retraces",
                hint="pin the leaf's dtype/shape (watch weak-type scalars "
                     "from Python literals and silent upcasts)",
                context=f"signature::{name}::aval"))
    return findings


def check_trace_signature(built: registry.BuiltCase) -> List[Finding]:
    findings: List[Finding] = []
    struct = built.state_struct
    for leaf_path, leaf in jax.tree_util.tree_flatten_with_path(struct)[0]:
        if jnp.dtype(leaf.dtype).itemsize > 4:
            name = "/".join(shd._path_names(leaf_path))
            findings.append(Finding(
                rule="trace-signature",
                file=f"<case:{built.name}/state>", line=0,
                message=f"64-bit leaf {name!r} ({leaf.dtype}) in the "
                        f"serving state — an x64 leak splits signatures "
                        f"between x64/x32 processes",
                hint="keep serving-state leaves <= 32-bit",
                context=f"x64::{name}"))
    for name, fn, extra in _entry_points(built):
        findings += signature_findings(fn, struct,
                                       f"<case:{built.name}/{name}>", extra)
    return findings


# ---------------------------------------------------------------------------
# host-sync (jaxpr half)
# ---------------------------------------------------------------------------
SYNC_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "host_callback_call", "infeed", "outfeed", "debug_print",
})


def _walk_jaxpr(jaxpr, hits: List[str]) -> None:
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in SYNC_PRIMITIVES:
            hits.append(eqn.primitive.name)
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                name = type(sub).__name__
                if name == "ClosedJaxpr":
                    _walk_jaxpr(sub.jaxpr, hits)
                elif name == "Jaxpr":
                    _walk_jaxpr(sub, hits)


def jaxpr_sync_findings(fn: Callable, args: Sequence,
                        label: str) -> List[Finding]:
    """Flag callback/infeed primitives inside a jitted body: each one
    forces a device<->host round-trip per step, serializing the decode
    critical path (the inventory the async-serving work starts from)."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    hits: List[str] = []
    _walk_jaxpr(jaxpr.jaxpr, hits)
    return [Finding(
        rule="host-sync", file=label, line=0,
        message=f"host-sync primitive {p!r} inside the jitted body",
        hint="move host work outside the step (or waive with an inline "
             "repro-lint comment at the call site if it is debug-only)",
        context=f"{label}::prim::{p}")
        for p in sorted(set(hits))]


def check_host_sync(built: registry.BuiltCase) -> List[Finding]:
    findings: List[Finding] = []
    for name, fn, extra in _entry_points(built):
        findings += jaxpr_sync_findings(
            fn, (built.state_struct,) + tuple(extra),
            f"<case:{built.name}/{name}>")
    return findings


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
LEVEL1_CHECKS = (check_donation, check_sharding_coverage,
                 check_trace_signature, check_host_sync)


def run_level1(cases: Optional[Sequence[registry.Case]] = None
               ) -> List[Finding]:
    findings: List[Finding] = []
    for case in (cases if cases is not None else registry.CASES):
        built = registry.build_case(case)
        for check in LEVEL1_CHECKS:
            findings += check(built)
    return findings
