"""Training loop, checkpointing, data pipeline, scheduler, serving engine."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spec_engine import SpecConfig
from repro.data.pipeline import mixed_batches, packed_batches
from repro.data.tokenizer import ByteTokenizer
from repro.serving import ServingEngine
from repro.serving.scheduler import Request, Scheduler
from repro.train import AdamWConfig, init_train_state, make_train_step
from repro.train.checkpoint import load, save
from repro.train.optimizer import cosine_lr


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "def f(x):\n    return x + 1  # émoji ✓"
    assert tok.decode(tok.encode(s)) == s


def test_pipeline_shapes_and_sharding():
    bs = list(packed_batches("code", 2, 32, 3, shard=0, num_shards=2))
    assert len(bs) == 3 and bs[0].shape == (2, 33)
    b2 = list(packed_batches("code", 2, 32, 3, shard=1, num_shards=2))
    assert not np.array_equal(bs[0], b2[0])  # shards see different data


def test_cosine_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(cosine_lr(cfg, jnp.asarray(0))) < 0.2
    assert abs(float(cosine_lr(cfg, jnp.asarray(10))) - 1.0) < 1e-5
    assert abs(float(cosine_lr(cfg, jnp.asarray(100))) - 0.1) < 1e-2


def test_train_loss_decreases(tiny_dense_cfg):
    import dataclasses
    cfg = dataclasses.replace(tiny_dense_cfg, vocab_size=259)
    ts = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, AdamWConfig(total_steps=16,
                                                    warmup_steps=2)))
    losses = []
    for b in mixed_batches(4, 48, 12, seed=0):
        ts, m = step(ts, jnp.asarray(b))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_checkpoint_roundtrip(tmp_path, tiny_dense):
    cfg, params = tiny_dense
    p = str(tmp_path / "ckpt.npz")
    save(p, params)
    p2 = load(p, params)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_scheduler_buckets_and_batches():
    s = Scheduler(max_batch=2, buckets=(16, 32))
    for p in ["a" * 5, "b" * 6, "c" * 28, "d" * 7]:
        s.submit(Request(prompt=p, max_new_tokens=8))
    b1 = s.next_batch()
    assert len(b1.requests) == 2            # max_batch respected
    assert b1.tokens.shape[1] == 16         # smallest bucket
    b2 = s.next_batch()
    b3 = s.next_batch()
    assert s.next_batch() is None
    sizes = sorted([len(b2.requests), len(b3.requests)])
    assert sizes == [1, 1]


def test_serving_engine_spec_mode(tiny_dense):
    cfg, params = tiny_dense
    import dataclasses
    eng = ServingEngine(params, cfg,
                        SpecConfig(k=3, w=2, strategy="mixed",
                                   max_new_tokens=8),
                        max_batch=4)
    eng.submit("hello world", max_new_tokens=8)
    eng.submit("hello again", max_new_tokens=8)
    reqs = eng.serve_all()
    assert len(reqs) == 2
    for r in reqs:
        assert r.stats["new_tokens"] == 8
        assert r.stats["tokens_per_call"] >= 1.0
