"""Serving engine: ties the scheduler to the jitted speculative generator.

One ``ServingEngine`` owns (params, cfg, tables) and serves batched requests
with either plain greedy decoding or the paper's batched speculation —
switching is one constructor argument, which is the paper's P3
('plug-and-play', no model modification).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.ngram_tables import NGramTables, build_bigram, build_unigram
from ..core.spec_engine import SpecConfig, generate
from ..data.tokenizer import ByteTokenizer
from ..models import model as M
from ..models.config import ModelConfig
from .scheduler import Batch, Request, Scheduler


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig,
                 spec: Optional[SpecConfig] = None,
                 tables: Optional[NGramTables] = None,
                 max_batch: int = 8,
                 adaptive: bool = False):
        """``adaptive``: pick (k, w) per batch with the UCB controller
        (core/controller.py, beyond-paper) instead of a static setting."""
        self.params = params
        self.cfg = cfg
        self.spec = spec or SpecConfig(strategy="greedy")
        self.tok = ByteTokenizer()
        self.scheduler = Scheduler(max_batch=max_batch)
        self.controller = None
        if adaptive:
            from ..core.controller import AdaptiveKW
            self.controller = AdaptiveKW(cfg)
        if (self.spec.strategy != "greedy" or adaptive) and tables is None:
            tables = self.build_tables(k_max=max(self.spec.k, 25),
                                       w_max=max(self.spec.w, 16))
        self.tables = tables
        self._gen_cache: Dict = {}

    # ------------------------------------------------------------------
    def build_tables(self, k_max: int = 16, w_max: int = 16,
                     batch: int = 256) -> NGramTables:
        """One-off model sweep (paper: <1 min for a 7B on one A100)."""
        fwd = jax.jit(lambda t: M.forward(self.params, self.cfg,
                                          tokens=t)[0][:, -1])
        topk, chain = build_bigram(fwd, self.cfg.vocab_size, k_max=k_max,
                                   w_max=w_max, batch=batch)
        uni = build_unigram(self.params["embed"]["embedding"],
                            self.params["embed"].get(
                                "lm_head",
                                self.params["embed"]["embedding"].T),
                            k_max=k_max)
        return NGramTables(unigram_topk=uni, bigram_topk=topk,
                           bigram_chain=chain)

    # ------------------------------------------------------------------
    def submit(self, prompt: str, max_new_tokens: int = 64) -> Request:
        req = Request(prompt=prompt, max_new_tokens=max_new_tokens)
        self.scheduler.submit(req)
        return req

    def _gen_fn(self, max_new: int, kw=None):
        key = (max_new, kw)
        if key not in self._gen_cache:
            spec = dataclasses.replace(self.spec, max_new_tokens=max_new)
            if kw is not None:                      # adaptive controller arm
                k, w = kw
                strategy = ("greedy" if w == 0 else
                            ("mixed" if self.spec.strategy == "greedy"
                             else self.spec.strategy))
                spec = dataclasses.replace(spec, k=max(k, 1), w=max(w, 1),
                                           strategy=strategy)
            self._gen_cache[key] = jax.jit(
                lambda p, toks, tbl: generate(p, self.cfg, spec, toks, tbl))
        return self._gen_cache[key]

    def run_batch(self, batch: Batch) -> List[Request]:
        kw = self.controller.choose() if self.controller else None
        fn = self._gen_fn(batch.max_new_tokens, kw)
        t0 = time.perf_counter()
        buf, blen, stats = fn(self.params, jnp.asarray(batch.tokens),
                              self.tables)
        buf.block_until_ready()
        dt = time.perf_counter() - t0
        if self.controller:
            self.controller.update(
                kw, tokens=float(np.asarray(stats["tokens"]).sum()),
                calls=float(max(np.asarray(stats["calls"]).sum(), 1)))
        P = batch.tokens.shape[1]
        buf = np.asarray(buf)
        blen = np.asarray(blen)
        for i, req in enumerate(batch.requests):
            req.output = self.tok.decode(buf[i, P:blen[i]])
            req.stats = {
                "new_tokens": int(blen[i] - P),
                "model_calls": int(np.asarray(stats["calls"])[i]),
                "tokens_per_call": float(np.asarray(stats["tokens"])[i]
                                         / max(1, np.asarray(
                                             stats["calls"])[i])),
                "wall_time_s": dt,
            }
        return batch.requests

    def serve_all(self) -> List[Request]:
        done: List[Request] = []
        while True:
            batch = self.scheduler.next_batch()
            if batch is None:
                return done
            done.extend(self.run_batch(batch))
