"""Production mesh construction.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS *before* any jax import; tests
and benches see the single real device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod: 16x16 = 256 chips; multi-pod: 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU sharding tests (requires host-device override)."""
    return jax.make_mesh(shape, axes)
