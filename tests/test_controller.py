"""Adaptive (k, w) controller: converges to the best speedup arm."""
import numpy as np

from repro.core.controller import AdaptiveKW
from repro.models.config import ModelConfig


def _cfg():
    return ModelConfig(name="c", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=2, d_ff=128, vocab_size=61).validate()


def test_controller_explores_all_arms_first():
    c = AdaptiveKW(_cfg())
    seen = set()
    for _ in range(len(c.arms)):
        a = c.choose()
        assert a not in seen           # inf bonus forces one pull each
        seen.add(a)
        c.update(a, tokens=10, calls=10)
    assert seen == set(c.arms)


def test_controller_converges_to_best_ratio():
    rng = np.random.default_rng(0)
    c = AdaptiveKW(_cfg(), explore=0.05)
    # synthetic environment: acceptance grows with w but saturates; the
    # roofline slowdown makes huge (k,w) not worth it
    true_tpc = {(1, 0): 1.0, (5, 4): 2.0, (10, 4): 2.2, (10, 10): 2.6,
                (25, 2): 1.8}
    for _ in range(300):
        a = c.choose()
        tok = true_tpc[a] * 10 * (1 + 0.05 * rng.standard_normal())
        c.update(a, tokens=tok, calls=10)
    best = c.best_exploit()
    ratios = {a: true_tpc[a] / c.slow[a] for a in c.arms}
    assert best == max(ratios, key=ratios.get)


def test_controller_slowdown_prior_sane():
    c = AdaptiveKW(_cfg())
    assert c.slow[(1, 0)] == 1.0
    assert c.slow[(25, 2)] >= c.slow[(5, 4)] * 0.5  # monotone-ish in cost
    assert all(v >= 1.0 for v in c.slow.values())
