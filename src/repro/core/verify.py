"""Greedy acceptance logic for batched speculation (paper §4.1).

The verification model call already produced, for every draft row i, the
model's greedy next-token prediction after each of its w+1 input tokens
(``greedy[b, i, j]`` = argmax after consuming input j of row i, where input
0 is the last committed token and inputs 1..w are the draft).

Row i accepts n_i = length of the longest prefix of its draft matching the
model's own greedy predictions; the winner is the row with the largest n_i
(ties -> lowest row index, which under the mixed strategy prioritises the
context N-gram, matching the paper's ordering).  The winner always also
emits one *bonus* token (the model's prediction after its last accepted
token), so every call commits n* + 1 >= 1 tokens and the output equals plain
greedy decoding token-for-token.

Per-slot arm masking (DESIGN.md §9): ``k_eff``/``w_eff`` restrict slot b to
its arm's (k_b, w_b) sub-problem inside the shared (k_max, w_max) shapes —
rows >= k_b can never win and acceptance is truncated at w_b, so the result
is bit-identical to a dedicated (k_b, w_b) call (drafters are prefix-
consistent in both k and w; attention is causal per row).  w_b == 0
degenerates to plain greedy decoding: every row's n_acc is 0, row 0 wins,
and the single committed token is the model's prediction after the last
committed token.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class Acceptance(NamedTuple):
    tokens: jnp.ndarray    # (B, w+1) committed tokens (padded past n_commit)
    n_commit: jnp.ndarray  # (B,) = n* + 1
    winner: jnp.ndarray    # (B,) winning row index
    n_acc: jnp.ndarray     # (B, k) per-row accepted-draft lengths (stats)


def accept(drafts: jnp.ndarray, greedy: jnp.ndarray,
           k_eff: Optional[jnp.ndarray] = None,
           w_eff: Optional[jnp.ndarray] = None) -> Acceptance:
    """drafts: (B, k, w) int32; greedy: (B, k, w+1) int32 argmax predictions.

    ``k_eff`` (B,) / ``w_eff`` (B,) optionally mask slot b down to its arm's
    (k_b, w_b): acceptance stops at draft depth w_b and rows >= k_b are
    excluded from the winner argmax (their n_acc still reports the unmasked
    depth-truncated value for stats).
    """
    B, k, w = drafts.shape
    eq = drafts == greedy[..., :w]
    if w_eff is not None:
        eq = eq & (jnp.arange(w)[None, None, :] < w_eff[:, None, None])
    n_acc = jnp.cumprod(eq.astype(jnp.int32), axis=-1).sum(axis=-1)  # (B,k)
    n_rank = n_acc
    if k_eff is not None:
        n_rank = jnp.where(jnp.arange(k)[None, :] < k_eff[:, None],
                           n_acc, -1)
    winner = jnp.argmax(n_rank, axis=-1).astype(jnp.int32)           # (B,)
    n_win = jnp.take_along_axis(n_acc, winner[:, None], axis=1)[:, 0]
    d_win = jnp.take_along_axis(drafts, winner[:, None, None],
                                axis=1)[:, 0]                         # (B,w)
    g_win = jnp.take_along_axis(greedy, winner[:, None, None],
                                axis=1)[:, 0]                         # (B,w+1)
    pos = jnp.arange(w + 1)[None, :]
    bonus = jnp.take_along_axis(g_win, n_win[:, None], axis=1)        # (B,1)
    d_pad = jnp.concatenate([d_win, jnp.zeros((B, 1), d_win.dtype)], axis=1)
    tokens = jnp.where(pos < n_win[:, None], d_pad,
                       jnp.where(pos == n_win[:, None], bonus, 0))
    return Acceptance(tokens=tokens.astype(jnp.int32),
                      n_commit=(n_win + 1).astype(jnp.int32),
                      winner=winner, n_acc=n_acc)
