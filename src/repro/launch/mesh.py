"""Production mesh construction.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS *before* any jax import; tests
and benches see the single real device).
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod: 16x16 = 256 chips; multi-pod: 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=None):
    """Small mesh for CPU sharded serving/tests (requires the host-device
    override — launch/hostdev.py — or enough real devices).  2 dims name
    ("data", "model"), 3 name ("pod", "data", "model"), matching the
    production mesh's axis vocabulary so every sharding rule applies."""
    if axes is None:
        axes = ("pod", "data", "model") if len(shape) == 3 \
            else ("data", "model")
    if jax.device_count() < math.prod(shape):
        raise RuntimeError(
            f"debug mesh {shape} needs {math.prod(shape)} "
            f"devices but jax sees {jax.device_count()} — launch with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N (the "
            f"--mesh entry points set it for you when it is absent)")
    return jax.make_mesh(shape, axes)
