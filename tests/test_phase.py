"""Roofline phase model (paper §3 / Fig. 1 analogue) sanity tests."""
import pytest

from repro.configs import get_config
from repro.core.phase import (CallCost, expected_speedup, slowdown,
                              verify_call_cost)


@pytest.fixture(scope="module")
def mistral():
    return get_config("mistral-7b")


def test_decode_call_is_memory_bound(mistral):
    c = verify_call_cost(mistral, ell=512, k=1, w=0)
    assert not c.compute_bound          # classic 1-token decode


def test_slowdown_monotone_in_k_and_w(mistral):
    base = slowdown(mistral, 500, 1, 0)
    assert base == pytest.approx(1.0)
    s_small = slowdown(mistral, 500, 5, 4)
    s_big = slowdown(mistral, 500, 25, 14)
    assert 1.0 <= s_small <= s_big


def test_free_region_exists(mistral):
    """Small (k,w) must be ~free while memory-bound (the paper's premise)."""
    assert slowdown(mistral, 500, 2, 1) < 1.2


def test_compute_bound_transition(mistral):
    """Large enough (k,w) must eventually slow the call down (Fig. 1)."""
    assert slowdown(mistral, 25, 32, 15) > 1.5


def test_shared_cache_beats_paper_layout_at_long_context(mistral):
    """Bifurcated shared-cache layout (ours) vs replicated (paper):
    at long context the k× cache re-read must cost real time."""
    s_shared = slowdown(mistral, 32768, 10, 10, shared_cache=True)
    s_paper = slowdown(mistral, 32768, 10, 10, shared_cache=False)
    assert s_paper > s_shared * 1.2


def test_expected_speedup_combines(mistral):
    sp = expected_speedup(mistral, 500, 10, 10, tokens_per_call=2.2)
    assert 0.5 < sp <= 2.2


def test_callcost_algebra():
    a = CallCost(10.0, 4.0)
    b = a * 2 + a
    assert b.flops == 30.0 and b.hbm_bytes == 12.0
