"""End-to-end behaviour: train a tiny model on synthetic code, build tables
from its own weights (P1/P2), then show batched speculation accelerates it
(tokens/call > 1) while matching greedy output exactly (the paper's claim)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ngram_tables import NGramTables, build_bigram, build_unigram
from repro.core.spec_engine import SpecConfig, generate, greedy_reference
from repro.data.pipeline import packed_batches
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.train import AdamWConfig, init_train_state, make_train_step


@pytest.fixture(scope="module")
def trained():
    cfg = ModelConfig(name="sys", num_layers=2, d_model=96, num_heads=4,
                      num_kv_heads=2, d_ff=192, vocab_size=259,
                      param_dtype=jnp.float32,
                      compute_dtype=jnp.float32).validate()
    ts = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, AdamWConfig(
        lr=1e-3, total_steps=60, warmup_steps=5)))
    for b in packed_batches("code", 8, 96, 60, seed=0):
        ts, m = step(ts, jnp.asarray(b))
    return cfg, ts["params"], float(m["loss"])


def test_system_spec_speedup_on_trained_model(trained):
    cfg, params, final_loss = trained
    assert final_loss < 2.0  # learned the templated code distribution
    fwd = jax.jit(lambda t: M.forward(params, cfg, tokens=t)[0][:, -1])
    topk, chain = build_bigram(fwd, cfg.vocab_size, k_max=10, w_max=10)
    uni = build_unigram(params["embed"]["embedding"],
                        params["embed"]["lm_head"], k_max=10)
    tables = NGramTables(uni, topk, chain)
    tok = ByteTokenizer()
    prompt = jnp.asarray(tok.encode_batch(
        ["def add_numbers(a, b):\n"], 24))
    N = 48
    ref = greedy_reference(params, cfg, prompt, N)
    spec = SpecConfig(k=5, w=5, strategy="mixed", max_new_tokens=N)
    buf, blen, stats = generate(params, cfg, spec, prompt, tables)
    np.testing.assert_array_equal(np.asarray(buf[:, :prompt.shape[1] + N]),
                                  np.asarray(ref))
    tpc = float(stats["tokens"][0] / stats["calls"][0])
    # a trained model on low-entropy code must beat 1.3 tokens/call
    assert tpc > 1.3, f"tokens/call={tpc}"
