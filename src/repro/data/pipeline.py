"""Training data pipeline: tokenize -> pack -> batch.

Deterministic, host-side (numpy) packing into fixed (B, T+1) blocks; the
train step slices inputs/labels.  For the multi-pod setting each data-
parallel shard would consume ``shard(index, num_shards)`` of the stream —
the iterator exposes that split explicitly.
"""
from __future__ import annotations

from typing import Iterator, List

import numpy as np

from .datasets import make_corpus
from .tokenizer import ByteTokenizer, EOS_ID


def token_stream(task: str, n_examples: int, seed: int = 0) -> np.ndarray:
    tok = ByteTokenizer()
    ids: List[int] = []
    for ex in make_corpus(task, n_examples, seed):
        ids.extend(tok.encode(ex, bos=True, eos=False))
        ids.append(EOS_ID)
    return np.asarray(ids, np.int32)


def packed_batches(task: str, batch: int, seq_len: int, steps: int,
                   seed: int = 0, shard: int = 0, num_shards: int = 1
                   ) -> Iterator[np.ndarray]:
    """Yields ``steps`` arrays of shape (batch, seq_len + 1) int32."""
    need = steps * batch * (seq_len + 1) * num_shards
    stream = token_stream(task, max(64, need // 40), seed)
    while stream.size < need:
        stream = np.concatenate([stream, token_stream(
            task, max(64, need // 40), seed + stream.size)])
    stream = stream[:need].reshape(num_shards, steps, batch, seq_len + 1)
    for i in range(steps):
        yield stream[shard, i]


def mixed_batches(batch: int, seq_len: int, steps: int, seed: int = 0
                  ) -> Iterator[np.ndarray]:
    """Equal-parts mixture of the three tasks (the quickstart train set)."""
    its = [packed_batches(t, batch, seq_len, steps, seed)
           for t in ("code", "math", "chat")]
    rng = np.random.default_rng(seed)
    for i in range(steps):
        parts = [next(it) for it in its]
        sel = rng.integers(0, 3, size=batch)
        yield np.stack([parts[sel[j]][j] for j in range(batch)])
