"""Analytic memory-bound -> compute-bound phase model (paper §3 / Fig. 1).

The paper measures the slowdown of a (k, w+1) verification call vs a (1, 1)
decode call on an A100 and observes the phase transition where matmuls cross
the GPU's ops-to-bytes threshold.  On TPU the analogue is the MXU ops:byte
ratio.  Since this container is CPU-only, we *derive* the call-time model
from FLOPs/bytes of each component (weights load, KV read, GEMM compute) and
TPU v5e hardware constants — each matmul contributes
max(flops/peak_flops, bytes/hbm_bw) (roofline time), summed over the layer.

This module is also used by the adaptive (k, w) controller (beyond-paper).
"""
from __future__ import annotations

import dataclasses

from ..models.config import ATTN, MOE, ModelConfig, layer_blocks

PEAK_FLOPS = 197e12        # TPU v5e bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link
BYTES_PER_EL = 2           # bf16


@dataclasses.dataclass
class CallCost:
    flops: float
    hbm_bytes: float

    @property
    def time(self) -> float:
        """Roofline execution time (s) on one chip."""
        return max(self.flops / PEAK_FLOPS, self.hbm_bytes / HBM_BW)

    @property
    def compute_bound(self) -> bool:
        return self.flops / PEAK_FLOPS > self.hbm_bytes / HBM_BW

    def __add__(self, o: "CallCost") -> "CallCost":
        return CallCost(self.flops + o.flops, self.hbm_bytes + o.hbm_bytes)

    def __mul__(self, s: float) -> "CallCost":
        return CallCost(self.flops * s, self.hbm_bytes * s)

    __rmul__ = __mul__


def _gemm(m: int, n: int, kk: int) -> CallCost:
    """(m,k)x(k,n) matmul: per-matmul roofline term."""
    return CallCost(2.0 * m * n * kk,
                    BYTES_PER_EL * (m * kk + kk * n + m * n))


def verify_call_cost(cfg: ModelConfig, ell: int, k: int, w: int,
                     shared_cache: bool = True) -> CallCost:
    """Cost of one verification model call: batch (k, w+1), context ell.

    ``shared_cache=False`` models the paper's layout (KV replicated k times,
    re-read per row); ``True`` models our bifurcated layout (read once).
    """
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    t = k * (w + 1)              # total query tokens in the call
    total = CallCost(0.0, 0.0)
    for b in layer_blocks(cfg):
        if b.mixer == ATTN:
            total += _gemm(t, H * hd, d) + _gemm(t, KV * hd, d) * 2
            total += _gemm(t, d, H * hd)
            # attention scores/values vs the cache
            ctx = min(ell, cfg.sliding_window or ell)
            cache_reads = 1 if shared_cache else k
            flops = 2.0 * k * (w + 1) * ctx * H * hd * 2   # qk^T and pv
            flops += 2.0 * k * (w + 1) * (w + 1) * H * hd * 2
            kv_bytes = BYTES_PER_EL * cache_reads * ctx * KV * hd * 2
            total += CallCost(flops, kv_bytes)
        else:
            # recurrent mixers: state-sized read/write + projections
            di = cfg.mamba_d_inner if b.mixer == "mamba" else 2 * d
            total += _gemm(t, 2 * di, d) + _gemm(t, d, di)
            total += CallCost(2.0 * t * di * 16,
                              4 * di * 16 * 2)  # state update (f32)
        if b.mlp == MOE:
            e_ff = cfg.expert_d_ff
            n_act = cfg.num_experts_per_tok + cfg.num_shared_experts
            # active expert FLOPs; weight bytes for every *touched* expert
            touched = min(cfg.num_experts, t * cfg.num_experts_per_tok)
            total += CallCost(2.0 * 3 * t * n_act * d * e_ff,
                              BYTES_PER_EL * 3 * d * e_ff * touched)
        elif b.mlp in ("swiglu", "geglu"):
            total += _gemm(t, cfg.d_ff, d) * 2 + _gemm(t, d, cfg.d_ff)
        elif b.mlp in ("relu2", "gelu"):
            total += _gemm(t, cfg.d_ff, d) + _gemm(t, d, cfg.d_ff)
    total += _gemm(t, cfg.vocab_size, d)   # lm head
    return total


def slowdown(cfg: ModelConfig, ell: int, k: int, w: int,
             shared_cache: bool = True) -> float:
    """Fig. 1 quantity: time(k, w+1 | ell) / time(1, 1 | ell)."""
    base = verify_call_cost(cfg, ell, 1, 0, shared_cache).time
    return verify_call_cost(cfg, ell, k, w, shared_cache).time / base


def expected_speedup(cfg: ModelConfig, ell: int, k: int, w: int,
                     tokens_per_call: float,
                     shared_cache: bool = True) -> float:
    """Modelled wall-time speedup = tokens_per_call / slowdown."""
    return tokens_per_call / slowdown(cfg, ell, k, w, shared_cache)
