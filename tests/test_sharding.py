"""Sharding-rule unit tests (no multi-device backend needed: rules are pure
functions of mesh *shape*; we build a Mesh over 1 real device is impossible
for 16x16, so we test the PartitionSpec logic through a fake mesh object)."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.distributed import sharding as shd
from repro.models.config import BlockSpec, ModelConfig


class FakeMesh:
    """Duck-typed stand-in: the rules only read ``mesh.shape``."""

    def __init__(self, shape_dict):
        self.shape = shape_dict


POD = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_resolve_axis_divisibility_fallback():
    assert shd.resolve_axis(POD, "kv", 8) is None          # 8 % 16 != 0
    assert shd.resolve_axis(POD, "kv", 32) == "model"
    assert shd.resolve_axis(POD, "embed", 4096) == "data"
    assert shd.resolve_axis(MULTI, "embed", 4096) == ("pod", "data")
    assert shd.resolve_axis(MULTI, "embed", 16) == "data"  # 16 % 32 != 0
    assert shd.resolve_axis(POD, None, 123) is None


class _Leaf:
    def __init__(self, shape):
        self.shape = shape


class _K:
    def __init__(self, key):
        self.key = key


def test_param_pspec_attention():
    spec = shd.param_pspec(POD, (_K("p0"), _K("mixer"), _K("wq")),
                           _Leaf((32, 4096, 8192)))
    assert tuple(spec) == (None, "data", "model")
    # kv proj with kv*hd=1024 divisible
    spec = shd.param_pspec(POD, (_K("p0"), _K("mixer"), _K("wk")),
                           _Leaf((32, 4096, 1024)))
    assert tuple(spec) == (None, "data", "model")


def test_param_pspec_moe_expert_fallback():
    # 16 experts: shard expert dim
    spec = shd.param_pspec(POD, (_K("p1"), _K("mlp"), _K("w_gate")),
                           _Leaf((9, 16, 8192, 24576)))
    assert tuple(spec) == (None, "model", "data", None)
    # 8 experts (mixtral): not divisible -> shard ffn instead
    spec = shd.param_pspec(POD, (_K("p0"), _K("mlp"), _K("w_gate")),
                           _Leaf((32, 8, 4096, 14336)))
    assert tuple(spec) == (None, None, "data", "model")


def test_state_pspec_kv_cache():
    # kv=8 not divisible by model=16 -> shard the cache SEQUENCE (it-5)
    spec = shd.state_pspec(POD, (_K("groups"), _K("p0"), _K("k")),
                           _Leaf((32, 128, 32768, 8, 128)))
    assert tuple(spec) == (None, "data", "model", None, None)
    # kv=32 divisible
    spec = shd.state_pspec(POD, (_K("groups"), _K("p0"), _K("k")),
                           _Leaf((24, 128, 32768, 32, 64)))
    assert tuple(spec) == (None, "data", None, "model", None)
    # batch=1 (long_500k), kv non-divisible: seq goes to "model"
    spec = shd.state_pspec(POD, (_K("groups"), _K("p0"), _K("k")),
                           _Leaf((32, 1, 8192, 8, 128)))
    assert tuple(spec) == (None, None, "model", None, None)


def test_state_pspec_recurrent():
    spec = shd.state_pspec(POD, (_K("groups"), _K("p0"), _K("ssm")),
                           _Leaf((63, 128, 16384, 16)))
    assert tuple(spec) == (None, "data", "model", None)
    # mlstm C: nh=4 not divisible -> shard dh
    spec = shd.state_pspec(POD, (_K("groups"), _K("p0"), _K("C")),
                           _Leaf((9, 32, 4, 384, 384)))
    assert tuple(spec) == (None, "data", None, "model", None)


def test_resolve_axis_warns_once_on_replication_fallback():
    """Silent degradation to replication must be surfaced: one
    ShardingFallbackWarning per (logical, dim, mesh), never repeated, and
    suppressed for probe call sites (warn=False) and size-1 dims."""
    shd.reset_fallback_warnings()
    with pytest.warns(shd.ShardingFallbackWarning, match="'vocab'"):
        assert shd.resolve_axis(POD, "vocab", 61) is None
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # second time: silent
        assert shd.resolve_axis(POD, "vocab", 61) is None
        assert shd.resolve_axis(POD, "kv", 8, warn=False) is None
        assert shd.resolve_axis(POD, "embed", 1) is None    # size-1 is free
        assert shd.resolve_axis(POD, "embed", 4096) == "data"  # divisible
    # a DIFFERENT mesh shape for the same (axis, dim) warns again
    with pytest.warns(shd.ShardingFallbackWarning):
        assert shd.resolve_axis(MULTI, "vocab", 61) is None
    assert ("vocab", 61) in shd.fallback_report()
    shd.reset_fallback_warnings()
    assert shd.fallback_report() == []


def _dpath(*names):
    return tuple(_K(n) for n in names)


def test_decode_state_pspec_serving_leaves():
    """Serving-level DecodeState leaves: slot axis over data, trailing dims
    replicated; model-cache leaves keep the state_pspec rules."""
    mesh = FakeMesh({"data": 2, "model": 2})
    assert tuple(shd.decode_state_pspec(mesh, _dpath("buf"),
                                        _Leaf((4, 64)))) == ("data", None)
    assert tuple(shd.decode_state_pspec(mesh, _dpath("done"),
                                        _Leaf((4,)))) == ("data",)
    assert tuple(shd.decode_state_pspec(
        mesh, _dpath("stats", "accept_hist"),
        _Leaf((4, 6)))) == ("data", None)
    # sampling leaves (DESIGN.md §12) are ordinary per-slot rows: the
    # rng key's trailing (2,) stays replicated, the controls slot-shard
    assert tuple(shd.decode_state_pspec(mesh, _dpath("rng_key"),
                                        _Leaf((4, 2)))) == ("data", None)
    assert tuple(shd.decode_state_pspec(mesh, _dpath("temperature"),
                                        _Leaf((4,)))) == ("data",)
    assert tuple(shd.decode_state_pspec(mesh, _dpath("top_p"),
                                        _Leaf((4,)))) == ("data",)
    # odd slot count -> replicated, not an error
    assert tuple(shd.decode_state_pspec(mesh, _dpath("buf_len"),
                                        _Leaf((3,)))) == (None,)
    # linear cache under the model subtree: kv=2 divides model=2
    assert tuple(shd.decode_state_pspec(
        mesh, _dpath("model", "groups", "p0", "k"),
        _Leaf((1, 4, 32, 2, 16)))) == (None, "data", None, "model", None)


def test_decode_state_pspec_paged_pool():
    """The paged pool's page axis shards like the sequence axis (ROADMAP):
    over data when kv takes the model axis, extended over model when the
    kv heads cannot; bookkeeping stays slot-sharded / replicated."""
    mesh = FakeMesh({"data": 2, "model": 2})
    # kv=2 divides model -> pages over data only
    spec = shd.decode_state_pspec(mesh, _dpath("model", "groups", "p0", "k"),
                                  _Leaf((1, 16, 8, 2, 16)), paged=True)
    assert tuple(spec) == (None, "data", None, "model", None)
    # kv=1 (MQA) cannot take model -> pages over (data, model)
    spec = shd.decode_state_pspec(mesh, _dpath("model", "groups", "p0", "v"),
                                  _Leaf((1, 16, 8, 1, 16)), paged=True)
    assert tuple(spec) == (None, ("data", "model"), None, None, None)
    assert tuple(shd.decode_state_pspec(
        mesh, _dpath("model", "page_table"),
        _Leaf((4, 8)))) == ("data", None)
    assert tuple(shd.decode_state_pspec(
        mesh, _dpath("model", "free_list"), _Leaf((16,)))) == (None,)
    assert tuple(shd.decode_state_pspec(
        mesh, _dpath("model", "free_top"), _Leaf(()))) == ()


def test_decode_state_shardings_walks_real_state_paths():
    """Path-name extraction must understand the registered-dataclass
    GetAttrKey entries a real DecodeState flattens to (decode_state_pspec
    keys rules on those names)."""
    import jax.numpy as jnp

    from repro.core.spec_engine import DecodeState
    B, L = 2, 8
    state = DecodeState(
        buf=jnp.zeros((B, L), jnp.int32),
        buf_len=jnp.zeros((B,), jnp.int32),
        prompt_len=jnp.zeros((B,), jnp.int32),
        budget=jnp.zeros((B,), jnp.int32),
        eos_id=jnp.zeros((B,), jnp.int32),
        done=jnp.zeros((B,), bool),
        active=jnp.zeros((B,), bool),
        model={"cur_len": jnp.zeros((B,), jnp.int32),
               "groups": {"p0": {"k": jnp.zeros((1, B, L, 2, 4)),
                                 "v": jnp.zeros((1, B, L, 2, 4))}}},
        stats={"calls": jnp.zeros((B,), jnp.int32)},
        rng_key=jnp.zeros((B, 2), jnp.uint32),
        temperature=jnp.zeros((B,), jnp.float32),
        top_p=jnp.ones((B,), jnp.float32))
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    names = {"/".join(shd._path_names(p)) for p, _ in flat}
    assert "buf" in names
    assert "model/groups/p0/k" in names
    assert "stats/calls" in names
    assert "rng_key" in names and "temperature" in names


def test_act_sharding_activated_scoped_and_exception_safe():
    """The scoped installer restores the PREVIOUS sharder on exit — even on
    exception, even nested — and uninstall() clears a bare install()."""
    from repro.distributed import act_sharding as act
    mesh_a, mesh_b = object(), object()     # only identity matters here
    assert not act.installed()
    with act.activated(mesh_a):
        assert act.installed()
        with act.activated(mesh_b):
            assert act._MESH is mesh_b
        assert act._MESH is mesh_a          # restored, not cleared
    assert not act.installed()
    with pytest.raises(RuntimeError):
        with act.activated(mesh_a):
            raise RuntimeError("boom")
    assert not act.installed()
    act.install(mesh_a)
    assert act.installed()
    act.uninstall()
    assert not act.installed()


def test_mesh_toggles_pallas_eligibility_gate():
    """attn_verify's backend gate (the documented dispatch seam): the
    Pallas kernel is eligible exactly while NO activation sharder is
    installed — and a scoped activation must round-trip the gate."""
    import jax.numpy as jnp

    from repro.distributed import act_sharding as act
    from repro.models.attention import _use_verify_kernel
    cfg = ModelConfig(name="gate", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=32,
                      backend="pallas",
                      param_dtype=jnp.float32,
                      compute_dtype=jnp.float32).validate()
    cur = jnp.zeros((1,), jnp.int32)
    assert _use_verify_kernel(cfg, cur)
    with act.activated(object()):
        assert not _use_verify_kernel(cfg, cur)     # mesh pins XLA
    assert _use_verify_kernel(cfg, cur)             # eligibility restored


def test_mesh_pins_ngram_sweep_to_xla(monkeypatch):
    """Same seam for the DRAFTER sweep: the Pallas ngram kernel is a
    single-device pallas_call the SPMD partitioner cannot split, so an
    installed activation sharder must route ngram_sweep to the XLA path
    (and back, once the mesh scope exits)."""
    import jax.numpy as jnp

    from repro.distributed import act_sharding as act
    from repro.kernels import dispatch, ops
    hits = {"n": 0}
    real = ops.ngram_match_op

    def spy(*a, **k):
        hits["n"] += 1
        return real(*a, **k)

    monkeypatch.setattr(ops, "ngram_match_op", spy)
    buf = jnp.zeros((1, 16), jnp.int32)
    query = jnp.zeros((1, 1), jnp.int32)
    cur = jnp.full((1,), 8, jnp.int32)
    with act.activated(object()):
        m_x, h_x = dispatch.ngram_sweep(buf, query, cur, w=2,
                                        backend="pallas")
    assert hits["n"] == 0, "mesh-active sweep must take the XLA path"
    m_p, h_p = dispatch.ngram_sweep(buf, query, cur, w=2, backend="pallas")
    assert hits["n"] == 1                            # eligibility restored
    import numpy as np
    np.testing.assert_array_equal(np.asarray(m_x), np.asarray(m_p))
    np.testing.assert_array_equal(np.asarray(h_x), np.asarray(h_p))


def test_hostdev_mesh_parsing_and_env_hygiene():
    """The --mesh entry-point helper: shape parsing, argv peeking, and —
    since jax is already imported in this process — refusing to touch the
    environment (the device count is locked; mutating XLA_FLAGS now would
    only mislead subprocesses)."""
    import os

    from repro.launch import hostdev
    assert hostdev.parse_mesh_shape("2x2") == (2, 2)
    assert hostdev.parse_mesh_shape("2x4x2") == (2, 4, 2)
    for bad in ("2", "0x2", "ax2", "2x2x2x2"):
        with pytest.raises(ValueError):
            hostdev.parse_mesh_shape(bad)
    assert hostdev.mesh_arg(["prog", "--mesh", "2x2"]) == "2x2"
    assert hostdev.mesh_arg(["prog", "--mesh=4x1"]) == "4x1"
    assert hostdev.mesh_arg(["prog", "--paged"]) is None
    before = os.environ.get("XLA_FLAGS")
    assert hostdev.ensure_host_devices(8) is False      # jax imported
    assert os.environ.get("XLA_FLAGS") == before


def test_debug_mesh_clear_error_without_devices():
    """On a single-device process a debug mesh must fail with the
    launch-with-XLA_FLAGS recipe, not an opaque jax shape error."""
    from repro.launch.mesh import make_debug_mesh
    if jax.device_count() >= 4:
        pytest.skip("placeholder devices present (sharded lane)")
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        make_debug_mesh((2, 2))


def test_every_assigned_arch_has_full_param_coverage():
    """Every leaf of every assigned arch gets a VALID PartitionSpec (rank
    matches) under both meshes — rule gaps would silently replicate."""
    import jax

    from repro.configs import ALL_ARCHS, get_config
    from repro.models import model as M
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda r: M.init_params(r, cfg),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        for mesh in (POD, MULTI):
            for path, leaf in jax.tree_util.tree_flatten_with_path(
                    shapes)[0]:
                spec = shd.param_pspec(mesh, path, leaf)
                assert len(spec) == len(leaf.shape), (arch, path)
                # spec axes must divide the dim
                for ax, d in zip(spec, leaf.shape):
                    if ax is None:
                        continue
                    axes = (ax,) if isinstance(ax, str) else ax
                    size = 1
                    for a in axes:
                        size *= mesh.shape[a]
                    assert d % size == 0, (arch, path, spec, leaf.shape)
