"""Validate dry-run artifacts when present (deliverable e gate).

These tests are skipped until ``python -m repro.launch.dryrun --all`` has
produced experiments/dryrun/*.json; once present, every non-skip case must
have compiled, and skips must match the documented DESIGN.md §5 set.
"""
import glob
import json
import os

import pytest

ART_DIR = os.path.join(os.path.dirname(__file__), "..",
                       "experiments", "dryrun")

EXPECTED_SKIPS = {("hubert-xlarge", "decode_32k"),
                  ("hubert-xlarge", "long_500k")}


def _records(mesh_tag):
    files = glob.glob(os.path.join(ART_DIR, f"*__{mesh_tag}__base.json"))
    return [json.load(open(f)) for f in files]


@pytest.mark.parametrize("mesh_tag", ["pod", "multipod"])
def test_dryrun_matrix(mesh_tag):
    recs = _records(mesh_tag)
    if not recs:
        pytest.skip(f"no {mesh_tag} dry-run artifacts yet "
                    "(run python -m repro.launch.dryrun --all)")
    fails = [(r["arch"], r["shape"]) for r in recs
             if r.get("status") == "fail"]
    assert not fails, f"dry-run failures: {fails}"
    skips = {(r["arch"], r["shape"]) for r in recs
             if r.get("status") == "skip"}
    assert skips <= EXPECTED_SKIPS, f"unexpected skips: {skips}"
    oks = [r for r in recs if r.get("status") == "ok"]
    for r in oks:
        assert r["cost"].get("flops", 0) > 0, r["arch"]
        assert r["memory"].get("total_hbm_bytes", 0) > 0, r["arch"]


def test_pod_matrix_complete_when_present():
    recs = _records("pod")
    if len(recs) < 40:
        pytest.skip(f"pod matrix incomplete ({len(recs)}/40)")
    assert len(recs) == 40
