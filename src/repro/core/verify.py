"""Acceptance logic for batched speculation: greedy and sampled (paper §4.1).

The verification model call already produced, for every draft row i, the
model's next-token logits after each of its w+1 input tokens.  Under greedy
decoding the per-position *prediction* is the argmax (``greedy[b, i, j]`` =
argmax after consuming input j of row i, where input 0 is the last committed
token and inputs 1..w are the draft).

Row i accepts n_i = length of the longest prefix of its draft matching the
model's own predictions; the winner is the row with the largest n_i
(ties -> lowest row index, which under the mixed strategy prioritises the
context N-gram, matching the paper's ordering).  The winner always also
emits one *bonus* token (the model's prediction after its last accepted
token), so every call commits n* + 1 >= 1 tokens and the output equals plain
greedy decoding token-for-token.

Lossless sampled verification (DESIGN.md §12): our n-gram drafts are
deterministic, so the speculative-sampling proposal is a POINT MASS and the
textbook rejection rule "accept token x with prob min(1, p(x)/q(x)); on
rejection resample from the renormalized residual (p - q)+" specialises to
"accept x with prob p(x); on rejection draw the bonus from p with x zeroed".
That per-event rule is realised here by *trajectory coupling*: instead of
per-token coin flips, ``sample_predictions`` draws ONE target sample per
(slot, tree level) from the temperature/top-p-shaped distribution via the
gumbel-max trick with a key folded from (slot step key, level).  Because
draft rows that are still alive at level j share their prefix (and therefore
their logits), they receive the SAME sample — so a single well-defined
sampled trajectory exists per slot, the longest-prefix walk in ``accept``
commits exactly its matching prefix, and the bonus token IS the first
trajectory token that diverged — i.e. a draw from the residual conditioned
on rejection.  The committed tokens equal the trajectory prefix regardless
of which row wins, which is what makes multi-row/tree verification lossless
(independent per-row coins would double-count: with rows [a], [b],
P(commit b) would be (1-p(a))·p(b) != p(b)).  With temperature == 0 the
prediction reduces bit-exactly to the argmax path above.

Per-slot arm masking (DESIGN.md §9, §11): ``masked_acceptance`` restricts
slot b to its arm's sub-problem inside the shared compile-time shapes.  The
"rows" here are linear draft rows in linear mode and root-to-leaf PATHS of
the draft tree in tree mode — the tree path-walk reuses this helper with a
``row_mask`` of path eligibility instead of the prefix mask ``k_eff``
induces.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np


class Acceptance(NamedTuple):
    tokens: jnp.ndarray    # (B, w+1) committed tokens (padded past n_commit)
    n_commit: jnp.ndarray  # (B,) = n* + 1
    winner: jnp.ndarray    # (B,) winning row index
    n_acc: jnp.ndarray     # (B, k) per-row accepted-draft lengths (stats)


def masked_acceptance(eq: jnp.ndarray,
                      k_eff: Optional[jnp.ndarray] = None,
                      w_eff: Optional[jnp.ndarray] = None,
                      row_mask: Optional[jnp.ndarray] = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Arm-mask a per-token match matrix down to per-row ranking scores.

    eq: (B, k, w) bool — token j of row i matched the model's greedy
    prediction.  Returns ``(n_acc, n_rank)``, both (B, k) int32:

      - ``n_acc[b, i]``  = longest matching prefix of row i, truncated at
        slot b's depth ``w_eff[b]`` when given (depth masking: a masked step
        may carry draft tokens past the slot's arm depth — zeros, stale
        shallower sweeps — that a dedicated run never drafted, so they must
        not extend acceptance);
      - ``n_rank[b, i]`` = n_acc with winner-INELIGIBLE rows forced to -1,
        so ``argmax(n_rank)`` can never select them while every eligible
        row (n_acc >= 0) still outranks them.  Eligibility is the AND of
        ``i < k_eff[b]`` (linear arms: rows are ordered best-first, an arm
        keeps a prefix) and ``row_mask[b, i]`` (tree arms: a
        (width_b, depth_b) arm keeps the paths whose branch choices all lie
        below width_b — NOT a prefix of the lex-ordered path list).

    Degenerate masks behave like the dedicated run they mask down to:
    ``w_eff == 0`` zeroes every n_acc (plain greedy: row/path 0 wins, only
    the bonus token commits); ``k_eff == 1`` makes row 0 the only candidate;
    an all-False eq changes nothing (bonus-only step).  At least one row
    must stay eligible — k_eff >= 1 and a row_mask containing the all-0
    branch path guarantee that by construction.
    """
    B, k, w = eq.shape
    if w_eff is not None:
        eq = eq & (jnp.arange(w)[None, None, :] < w_eff[:, None, None])
    n_acc = jnp.cumprod(eq.astype(jnp.int32), axis=-1).sum(axis=-1)  # (B,k)
    eligible = jnp.ones((B, k), bool)
    if k_eff is not None:
        eligible = eligible & (jnp.arange(k)[None, :] < k_eff[:, None])
    if row_mask is not None:
        eligible = eligible & row_mask
    n_rank = jnp.where(eligible, n_acc, -1)
    return n_acc, n_rank


def accept(drafts: jnp.ndarray, greedy: jnp.ndarray,
           k_eff: Optional[jnp.ndarray] = None,
           w_eff: Optional[jnp.ndarray] = None,
           row_mask: Optional[jnp.ndarray] = None) -> Acceptance:
    """drafts: (B, k, w) int32; greedy: (B, k, w+1) int32 argmax predictions.

    ``k_eff`` (B,) / ``w_eff`` (B,) / ``row_mask`` (B, k) optionally mask
    slot b down to its arm's sub-problem (see ``masked_acceptance``): rows
    outside the arm are excluded from the winner argmax and acceptance
    stops at the arm depth (excluded rows' n_acc still reports the unmasked
    depth-truncated value for stats).  In tree mode the "rows" are
    root-to-leaf paths gathered from the verified node tree.
    """
    B, k, w = drafts.shape
    eq = drafts == greedy[..., :w]
    n_acc, n_rank = masked_acceptance(eq, k_eff=k_eff, w_eff=w_eff,
                                      row_mask=row_mask)
    winner = jnp.argmax(n_rank, axis=-1).astype(jnp.int32)           # (B,)
    n_win = jnp.take_along_axis(n_acc, winner[:, None], axis=1)[:, 0]
    d_win = jnp.take_along_axis(drafts, winner[:, None, None],
                                axis=1)[:, 0]                         # (B,w)
    g_win = jnp.take_along_axis(greedy, winner[:, None, None],
                                axis=1)[:, 0]                         # (B,w+1)
    pos = jnp.arange(w + 1)[None, :]
    bonus = jnp.take_along_axis(g_win, n_win[:, None], axis=1)        # (B,1)
    d_pad = jnp.concatenate([d_win, jnp.zeros((B, 1), d_win.dtype)], axis=1)
    tokens = jnp.where(pos < n_win[:, None], d_pad,
                       jnp.where(pos == n_win[:, None], bonus, 0))
    return Acceptance(tokens=tokens.astype(jnp.int32),
                      n_commit=(n_win + 1).astype(jnp.int32),
                      winner=winner, n_acc=n_acc)


# ---------------------------------------------------------------------------
# sampled verification (DESIGN.md §12)
# ---------------------------------------------------------------------------

def _bcast_over(v: Union[float, jnp.ndarray], like: jnp.ndarray) -> jnp.ndarray:
    """Align a scalar or (B,) control to the LEADING dims of ``like`` by
    padding trailing singleton axes (numpy broadcasting aligns trailing)."""
    v = jnp.asarray(v, jnp.float32)
    return v.reshape(v.shape + (1,) * (like.ndim - v.ndim))


def shape_logits(logits: jnp.ndarray,
                 temperature: Union[float, jnp.ndarray],
                 top_p: Union[float, jnp.ndarray, None] = None) -> jnp.ndarray:
    """Shape raw logits into the target sampling distribution (f32).

    The ONE shaping function shared by every sampling site — the spec-path
    trajectory sampler, the plain-decode fallback, and the test oracle — so
    "spec sampling == plain sampling" is a property of the acceptance walk,
    never of two subtly different softmaxes.  Upcasts to float32 BEFORE the
    temperature division (fp16 logits / small t overflows), then applies
    nucleus (top-p) truncation: keep the smallest prefix of
    descending-probability tokens whose mass reaches ``top_p``, -inf the
    rest.  The top-1 token is always kept; ``top_p >= 1`` is a no-op.
    ``temperature`` entries <= 0 are clamped to 1 purely to keep the
    arithmetic finite — callers route those slots to argmax, never through
    the shaped distribution.
    """
    lf = logits.astype(jnp.float32)
    t = _bcast_over(temperature, lf)
    scaled = lf / jnp.where(t > 0, t, 1.0)
    if top_p is None:
        return scaled
    p = _bcast_over(top_p, lf)
    probs = jax.nn.softmax(scaled, axis=-1)
    srt = jnp.sort(probs, axis=-1)[..., ::-1]
    excl = jnp.cumsum(srt, axis=-1) - srt          # mass strictly above rank
    kept = excl < p                                 # always keeps rank 0
    thresh = jnp.min(jnp.where(kept, srt, jnp.inf), axis=-1, keepdims=True)
    keep = (probs >= thresh) | (p >= 1.0)
    return jnp.where(keep, scaled, -jnp.inf)


def residual_pmf(probs: jnp.ndarray, rejected: jnp.ndarray) -> jnp.ndarray:
    """Renormalized residual after a point-mass rejection.

    ``probs``: (..., V) target pmf; ``rejected``: (...,) int token ids.  For
    a point-mass proposal q = δ_x the textbook residual (p - min(p, q))+ is
    exactly p with x zeroed, and sampling it equals drawing t ~ p
    conditioned on t != x — the identity that lets ``sample_predictions``
    realise rejection sampling as trajectory coupling (no explicit residual
    draw in the jitted path; this helper exists for the contract and its
    property tests).  Callers guarantee probs[rejected] < 1.
    """
    p = probs.astype(jnp.float32)
    hit = jax.nn.one_hot(rejected, p.shape[-1], dtype=p.dtype)
    z = p * (1.0 - hit)
    return z / jnp.sum(z, axis=-1, keepdims=True)


def per_row_keys(rng: jnp.ndarray, batch: int) -> jnp.ndarray:
    """Expand one uint32 key (2,) to per-row keys (B, 2) via fold_in(row).

    Already-(B, 2) key arrays pass through untouched, so callers can hand
    either a base key or explicit per-request keys.
    """
    rng = jnp.asarray(rng, jnp.uint32)
    if rng.ndim == 1:
        return jax.vmap(lambda b: jax.random.fold_in(rng, b))(
            jnp.arange(batch))
    return rng


def sample_predictions(logits: jnp.ndarray, rng: jnp.ndarray,
                       temperature: jnp.ndarray, top_p: jnp.ndarray,
                       levels: Optional[np.ndarray] = None) -> jnp.ndarray:
    """Per-position target predictions for sampled verification.

    logits: (B, K, W1, V) f32 verify logits; rng: (B, 2) uint32 per-slot
    step keys; temperature/top_p: (B,) f32.  Returns (B, K, W1) int32
    predictions that drop into ``accept`` exactly where the argmax
    predictions go.

    The gumbel noise is keyed per (slot, LEVEL) — ``levels`` maps each of
    the W1 verify positions to its depth (linear mode: arange(W1); tree
    mode: the topology's ``pos_off``, so same-level nodes share noise).
    Rows/nodes alive at a level share their prefix, hence their logits,
    hence — with shared noise — their sample: the slot has one sampled
    trajectory and the acceptance walk commits its longest drafted prefix
    plus the first divergent (= residual) token.  Slots with
    temperature <= 0 return the argmax bit-exactly.
    """
    B, K, W1, V = logits.shape
    lv = np.arange(W1) if levels is None else np.asarray(levels)
    n_lv = int(lv.max()) + 1
    pred_greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    shaped = shape_logits(logits, temperature, top_p)

    def slot_noise(key: jnp.ndarray) -> jnp.ndarray:
        keys = jax.vmap(lambda l: jax.random.fold_in(key, l))(
            jnp.arange(n_lv))
        return jax.vmap(
            lambda k: jax.random.gumbel(k, (V,), jnp.float32))(keys)

    g = jax.vmap(slot_noise)(jnp.asarray(rng, jnp.uint32))   # (B, n_lv, V)
    g = g[:, jnp.asarray(lv, jnp.int32)]                     # (B, W1, V)
    sampled = jnp.argmax(shaped + g[:, None], axis=-1).astype(jnp.int32)
    return jnp.where((temperature > 0)[:, None, None], sampled, pred_greedy)


def sample_token(logits: jnp.ndarray, rng: jnp.ndarray,
                 temperature: jnp.ndarray, top_p: jnp.ndarray) -> jnp.ndarray:
    """Sample one next token per row: (B, V) logits -> (B,) int32.

    The single-position case of ``sample_predictions`` (level 0) — used for
    the plain-decode body, prefill first tokens, and admissions, so every
    sampling event in the engine shares one primitive and one key schedule.
    Rows with temperature <= 0 take the argmax bit-exactly.
    """
    return sample_predictions(logits[:, None, None, :], rng,
                              jnp.asarray(temperature, jnp.float32),
                              jnp.asarray(top_p, jnp.float32))[:, 0, 0]
