"""Figure 1 reproduction: memory-bound -> compute-bound phase transition.

The paper measures call slowdown of Mistral-7B on an A100 for
(k, w) in {1..32}x{0..15} at context lengths {25, 100, 500}.  We derive the
TPU-v5e analogue analytically from the per-matmul roofline (core/phase.py):
slowdown(k, w | ell) = T(k, w+1) / T(1, 1).  Wave quantization (an SM
artefact) has no TPU analogue; the crossover here is the MXU ops:byte knee.
"""
from __future__ import annotations

import csv
import os

from repro.configs import get_config
from repro.core.phase import slowdown

ELLS = (25, 100, 500, 4096, 32768)
KS = (1, 2, 4, 8, 16, 25, 32)
WS = (0, 1, 2, 4, 8, 10, 14)


def run(out_dir: str = "experiments/results") -> dict:
    cfg = get_config("mistral-7b")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "fig1_phase_transition.csv")
    rows = []
    with open(path, "w", newline="") as f:
        wr = csv.writer(f)
        wr.writerow(["ell", "k", "w", "slowdown_shared_cache",
                     "slowdown_paper_layout"])
        for ell in ELLS:
            for k in KS:
                for w in WS:
                    s_b = slowdown(cfg, ell, k, w, shared_cache=True)
                    s_p = slowdown(cfg, ell, k, w, shared_cache=False)
                    wr.writerow([ell, k, w, f"{s_b:.4f}", f"{s_p:.4f}"])
                    rows.append((ell, k, w, s_b, s_p))
    # headline numbers: where does (k,w)=(10,10) stop being ~free?
    free = {ell: slowdown(cfg, ell, 10, 10) for ell in ELLS}
    return {"csv": path, "slowdown_10_10": free,
            "max_slowdown": max(r[3] for r in rows)}


def main():
    res = run()
    print("fig1_phase_transition ->", res["csv"])
    for ell, s in res["slowdown_10_10"].items():
        print(f"  ell={ell:6d}: slowdown(k=10,w=10) = {s:.2f}x")


if __name__ == "__main__":
    main()
