"""Attention: MHA / GQA / MQA with RoPE variants, sliding windows, caches.

Covers every assigned attention flavour:
  - full-causal (StableLM, GLM4, Nemotron, Jamba attn layers, ...)
  - bidirectional (HuBERT encoder)
  - sliding-window causal (Mixtral; long-context dense variant)
  - partial-rotary RoPE (StableLM 25%, Nemotron 50%)
  - M-RoPE (Qwen2-VL, 3D t/h/w positions)
  - MQA (Gemma kv=1) and GQA groups

The decode/verify path attends to a cache buffer + the in-flight block, which
is exactly the shape the paper's batched (k, w+1) verification needs.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import MROPE, ROPE, ModelConfig
from .layers import dense_init

Params = Dict[str, jnp.ndarray]


def init_attention(rng, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(rng, 4)
    return {
        "wq": dense_init(ks[0], (d, cfg.num_heads * hd), cfg.param_dtype),
        "wk": dense_init(ks[1], (d, cfg.num_kv_heads * hd), cfg.param_dtype),
        "wv": dense_init(ks[2], (d, cfg.num_kv_heads * hd), cfg.param_dtype),
        "wo": dense_init(ks[3], (cfg.num_heads * hd, d), cfg.param_dtype),
    }


# ----------------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------------
def _rope_inv_freq(cfg: ModelConfig) -> jnp.ndarray:
    rd = cfg.rotary_dim
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))


def rope_freqs(cfg: ModelConfig, positions: jnp.ndarray) -> jnp.ndarray:
    """positions: (B, T) int32, or (3, B, T) for M-RoPE. Returns (B, T, rd/2)."""
    inv = _rope_inv_freq(cfg)  # (rd/2,)
    if cfg.rope == MROPE:
        assert positions.ndim == 3, "M-RoPE needs (3, B, T) positions"
        sec = jnp.asarray(cfg.mrope_sections)
        # section id for each rotary half-dim
        sec_id = jnp.repeat(jnp.arange(3), sec, total_repeat_length=inv.shape[0])
        # per-dim positions: select the t/h/w position row
        pos = positions.astype(jnp.float32)  # (3, B, T)
        pos_per_dim = pos[sec_id]            # (rd/2, B, T)
        return jnp.moveaxis(pos_per_dim, 0, -1) * inv  # (B, T, rd/2)
    pos = positions.astype(jnp.float32)
    return pos[..., None] * inv  # (B, T, rd/2)


def apply_rope(x: jnp.ndarray, freqs: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """x: (B, T, N, hd); freqs: (B, T, rd/2). NeoX half-split convention."""
    rd = cfg.rotary_dim
    if rd == 0:
        return x
    x_rot, x_pass = x[..., :rd], x[..., rd:]
    half = rd // 2
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    cos = jnp.cos(freqs)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(freqs)[:, :, None, :].astype(x.dtype)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    return jnp.concatenate([r1, r2, x_pass], axis=-1)


# ----------------------------------------------------------------------------
# core attention math (pure-jnp reference path; Pallas kernel is the TPU path)
# ----------------------------------------------------------------------------
# Above this many keys, full self-attention switches to the blockwise
# (flash-style, online-softmax) path: the (B,H,T,S) logits tensor of a 32k
# prefill is ~50 GiB/device even sharded — measured in EXPERIMENTS.md §Perf
# it-3 — while blockwise keeps only one (B,H,T,BS) slab live at a time.
BLOCKWISE_THRESHOLD = 8192
BLOCKWISE_BLOCK = 1024


def _blockwise_attention(q, k, v, q_pos, k_pos, cfg, causal: bool,
                         block: int = BLOCKWISE_BLOCK) -> jnp.ndarray:
    """Flash-style attention: scan over key blocks with online softmax.

    Same contract as ``masked_attention``; numerically identical softmax.
    """
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    nb = S // block
    assert S % block == 0
    qf = q.reshape(B, T, KV, G, hd).astype(jnp.float32)
    scale = 1.0 / (hd ** 0.5)

    def rs(a):  # (B,S,...) -> (nb, B, bs, ...)
        return jnp.moveaxis(a.reshape(B, nb, block, *a.shape[2:]), 1, 0)

    kb, vb, kpb = rs(k.astype(jnp.float32)), rs(v.astype(jnp.float32)), \
        rs(k_pos)

    def body(carry, xs):
        m, l, acc = carry
        k_c, v_c, kp_c = xs
        logits = jnp.einsum("btkgh,bskh->bkgts", qf, k_c) * scale
        if cfg.attn_logit_softcap:
            c = cfg.attn_logit_softcap
            logits = c * jnp.tanh(logits / c)
        valid = (kp_c >= 0)[:, None, None, None, :]
        if causal:
            valid = valid & (kp_c[:, None, :] <=
                             q_pos[:, :, None])[:, None, None]
        if cfg.sliding_window is not None:
            win = cfg.sliding_window
            valid = valid & (kp_c[:, None, :] >
                             q_pos[:, :, None] - win)[:, None, None]
        logits = jnp.where(valid, logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgts,bskh->bkgth", p, v_c)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, T), -1e30, jnp.float32)
    l0 = jnp.zeros((B, KV, G, T), jnp.float32)
    a0 = jnp.zeros((B, KV, G, T, hd), jnp.float32)
    from .runtime_flags import UNROLL_FOR_ANALYSIS
    if UNROLL_FOR_ANALYSIS:
        carry = (m0, l0, a0)
        for i in range(nb):
            carry, _ = body(carry, (kb[i], vb[i], kpb[i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, kpb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, -2, 1).reshape(B, T, H, hd).astype(q.dtype)


def masked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                     cfg: ModelConfig, causal: bool) -> jnp.ndarray:
    """q: (B,T,H,hd) k/v: (B,S,KV,hd); *_pos: (B,T)/(B,S) (-1 = invalid key).

    Returns (B, T, H, hd).  GQA via reshape to (KV, G) groups.
    Dispatches to the blockwise path for large key counts.
    """
    S = k.shape[1]
    if S >= BLOCKWISE_THRESHOLD and S % BLOCKWISE_BLOCK == 0:
        return _blockwise_attention(q, k, v, q_pos, k_pos, cfg, causal)
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.reshape(B, T, KV, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    logits = jnp.einsum("btkgh,bskh->bkgts", qf, kf) / (hd ** 0.5)
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        logits = c * jnp.tanh(logits / c)
    valid = (k_pos >= 0)[:, None, None, None, :]
    if causal:
        valid = valid & (k_pos[:, None, :] <= q_pos[:, :, None])[:, None, None]
    if cfg.sliding_window is not None:
        win = cfg.sliding_window
        valid = valid & (k_pos[:, None, :] > q_pos[:, :, None] - win)[:, None, None]
    logits = jnp.where(valid, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", w, v.astype(jnp.float32))
    return out.reshape(B, T, H, hd).astype(q.dtype)


# ----------------------------------------------------------------------------
# layer application
# ----------------------------------------------------------------------------
def qkv_project(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                freqs: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    cd = cfg.compute_dtype
    x = x.astype(cd)
    q = (x @ params["wq"].astype(cd)).reshape(B, T, cfg.num_heads, hd)
    k = (x @ params["wk"].astype(cd)).reshape(B, T, cfg.num_kv_heads, hd)
    v = (x @ params["wv"].astype(cd)).reshape(B, T, cfg.num_kv_heads, hd)
    if cfg.rope != "none":
        q = apply_rope(q, freqs, cfg)
        k = apply_rope(k, freqs, cfg)
    return q, k, v


def attn_full(params: Params, x: jnp.ndarray, cfg: ModelConfig,
              positions: jnp.ndarray,
              seq_mask: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray,
                                                               Tuple[jnp.ndarray,
                                                                     jnp.ndarray]]:
    """Self-attention over a full block (train / prefill).

    positions: (B, T) (or (3,B,T) for mrope). seq_mask: (B, T) bool for padding.
    Returns output and the (k, v) tensors for cache insertion.
    """
    freqs = rope_freqs(cfg, positions) if cfg.rope != "none" else None
    q, k, v = qkv_project(params, x, cfg, freqs)
    pos2d = positions[0] if positions.ndim == 3 else positions
    k_pos = pos2d if seq_mask is None else jnp.where(seq_mask, pos2d, -1)
    out = masked_attention(q, k, v, pos2d, k_pos, cfg, causal=cfg.causal)
    B, T, _, _ = out.shape
    y = out.reshape(B, T, -1) @ params["wo"].astype(cfg.compute_dtype)
    return y, (k, v)


def _verify_attention_xla(q, k_cache, v_cache, k_tail, v_tail, cache_pos,
                          pos2d, cfg: ModelConfig,
                          tail_mask=None) -> jnp.ndarray:
    """XLA backend of the bifurcated verify attention.

    q: (B,K,W1,H,hd); caches (B,S,KV,hd); tails (B,K,W1,KV,hd);
    cache_pos: (B,S) absolute position per slot (-1 = empty, ring-aware);
    pos2d: (B,W1) query positions.  ``tail_mask``: optional STATIC
    (W1, W1) bool tail visibility replacing the causal triangle — tree
    verification's ancestor mask (DESIGN.md §11; K == 1 there).
    Returns (B,K,W1,H,hd) f32.

    This is the fully-general path (softcap, sliding-window ring caches,
    sharded context logits); the Pallas backend covers the linear-cache
    subset via kernels/dispatch.verify_attention.
    """
    B, K, W1, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, K, W1, KV, G, hd).astype(jnp.float32)
    kn = k_tail.astype(jnp.float32)
    vn = v_tail.astype(jnp.float32)
    kc = k_cache.astype(jnp.float32)
    vc = v_cache.astype(jnp.float32)
    scale = 1.0 / (hd ** 0.5)
    # context logits: shared cache read once per sequence
    lc = jnp.einsum("bkwnGh,bsnh->bknGws", qg, kc) * scale
    from ..distributed import act_sharding
    lc = act_sharding.constrain(lc, "ctx_logits")   # (B,K,n,G,w1,S)
    if cfg.attn_logit_softcap:
        lc = cfg.attn_logit_softcap * jnp.tanh(lc / cfg.attn_logit_softcap)
    valid_c = (cache_pos >= 0)[:, None, None, None, None, :]
    if cfg.sliding_window is not None:
        win = cfg.sliding_window
        in_win = (cache_pos[:, None, :] > pos2d[:, :, None] - win)
        valid_c = valid_c & in_win[:, None, None, None]
    lc = jnp.where(valid_c, lc, -1e30)
    # local (per-row) logits: causal within the speculative tail
    ll = jnp.einsum("bkwnGh,bkvnh->bknGwv", qg, kn) * scale
    if cfg.attn_logit_softcap:
        ll = cfg.attn_logit_softcap * jnp.tanh(ll / cfg.attn_logit_softcap)
    if tail_mask is None:
        local = jnp.tril(jnp.ones((W1, W1), bool))
    else:
        # tree ancestor mask; applied within each of the K rows (tree mode
        # flattens the whole tree into the single row K == 1)
        local = jnp.asarray(tail_mask, bool)
    ll = jnp.where(local[None, None, None, None], ll, -1e30)
    # merged softmax WITHOUT concatenating [lc | ll]: a concat would force
    # the sharded context logits to be gathered; here only per-row max/sum
    # scalars cross the cache's sharding (flash-decode style, §Perf it-7).
    m = jnp.maximum(lc.max(axis=-1), ll.max(axis=-1))     # (b,k,n,G,w)
    e_c = jnp.exp(lc - m[..., None])
    e_l = jnp.exp(ll - m[..., None])
    denom = e_c.sum(axis=-1) + e_l.sum(axis=-1)
    out = (jnp.einsum("bknGws,bsnh->bkwnGh", e_c, vc)
           + jnp.einsum("bknGwv,bkvnh->bkwnGh", e_l, vn))
    out = act_sharding.constrain(out, "ctx_out")
    out = out / jnp.moveaxis(denom, -1, 2)[..., None]
    return out.reshape(B, K, W1, H, hd)


def _use_verify_kernel(cfg: ModelConfig, cur_len) -> bool:
    """Route to the Pallas kernel iff the backend resolves to pallas, the
    config is inside the kernel's contract (linear cache, no softcap) and
    the caller supplied the scalar-prefetch cur_len.  The mesh-sharded XLA
    path keeps its own flash-decode partitioning, so an installed
    activation-sharder also pins the XLA backend."""
    from ..distributed import act_sharding
    from ..kernels import dispatch
    return (cur_len is not None
            and dispatch.use_pallas(cfg.backend)
            and dispatch.pallas_verify_supported(cfg)
            and not act_sharding.installed())


def attn_verify(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                positions: jnp.ndarray,
                k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                cache_pos: jnp.ndarray,
                cur_len: Optional[jnp.ndarray] = None,
                page_table: Optional[jnp.ndarray] = None,
                tail_mask=None
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Bifurcated batched-speculation attention (the paper's verification).

    x: (B, k, w1, d) — k speculative rows per sequence.  Each row attends to
    the SHARED context cache (read once, not k times — beyond-paper
    optimisation, see DESIGN.md §3) plus its own (w1)-token tail, causally,
    with no cross-row attention.

    positions: (B, w1) or (3, B, w1) — identical for all k rows.
    tail_mask: optional STATIC (w1, w1) bool numpy array replacing the causal
    tail triangle — tree verification's ancestor-only visibility
    (DESIGN.md §11; the tree rides as the single row k == 1, so the
    (k*w1, k*w1) kernel mask and this per-row mask coincide).
    cur_len: (B,) committed cache length (linear caches); enables the Pallas
    backend (kernels/dispatch.py) when ``cfg.backend`` resolves to pallas.
    page_table: (B, pages_per_slot) when the cache is PAGED (DESIGN.md §8) —
    k_cache/v_cache are then the shared (num_pages, page_size, KV, hd) pool:
    the Pallas backend walks the table directly (one grid step per page);
    the XLA backend gathers the per-slot linear view first and reuses
    ``_verify_attention_xla`` unchanged, which is what the bit-parity tests
    pin against the linear layout.
    Returns (y (B,k,w1,d), k_new, v_new (B,k,w1,KV,hd)).
    """
    B, K, W1, d = x.shape
    hd = cfg.resolved_head_dim
    cd = cfg.compute_dtype
    freqs = rope_freqs(cfg, positions) if cfg.rope != "none" else None
    xf = x.reshape(B * K, W1, d).astype(cd)
    fr = None
    if freqs is not None:
        fr = jnp.repeat(freqs, K, axis=0)  # (B*K, w1, rd/2)
    q = (xf @ params["wq"].astype(cd)).reshape(B * K, W1, cfg.num_heads, hd)
    k_new = (xf @ params["wk"].astype(cd)).reshape(B * K, W1,
                                                   cfg.num_kv_heads, hd)
    v_new = (xf @ params["wv"].astype(cd)).reshape(B * K, W1,
                                                   cfg.num_kv_heads, hd)
    if cfg.rope != "none":
        q = apply_rope(q, fr, cfg)
        k_new = apply_rope(k_new, fr, cfg)
    KV = cfg.num_kv_heads
    qk = q.reshape(B, K, W1, cfg.num_heads, hd)
    kn = k_new.reshape(B, K, W1, KV, hd)
    vn = v_new.reshape(B, K, W1, KV, hd)
    pos2d = positions[0] if positions.ndim == 3 else positions  # (B, w1)
    if page_table is not None:
        if _use_verify_kernel(cfg, cur_len):
            from ..kernels import dispatch
            out = dispatch.verify_attention_paged(qk, k_cache, v_cache,
                                                  page_table, kn, vn,
                                                  cur_len, w1=W1,
                                                  tail_mask=tail_mask)
        else:
            from .cache import gather_pages
            k_lin, v_lin = gather_pages(k_cache, v_cache, page_table)
            out = _verify_attention_xla(qk, k_lin, v_lin, kn, vn, cache_pos,
                                        pos2d, cfg, tail_mask=tail_mask)
    elif _use_verify_kernel(cfg, cur_len):
        from ..kernels import dispatch
        out = dispatch.verify_attention(qk, k_cache, v_cache, kn, vn,
                                        cur_len, w1=W1,
                                        block_s=cfg.kernel_block_s,
                                        tail_mask=tail_mask)
    else:
        out = _verify_attention_xla(qk, k_cache, v_cache, kn, vn, cache_pos,
                                    pos2d, cfg, tail_mask=tail_mask)
    out = out.reshape(B, K, W1, cfg.num_heads * hd).astype(cd)
    y = out @ params["wo"].astype(cd)
    return y, kn, vn


