from . import datasets, pipeline, tokenizer  # noqa: F401
from .tokenizer import ByteTokenizer  # noqa: F401
