from . import checkpoint, optimizer, train_loop  # noqa: F401
from .optimizer import AdamWConfig  # noqa: F401
from .train_loop import init_train_state, make_train_step  # noqa: F401
