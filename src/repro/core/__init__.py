"""The paper's primary contribution: learning-free batched speculation."""
from . import drafters, ngram_tables, phase, spec_engine, verify  # noqa: F401
from .ngram_tables import NGramTables, build_bigram, build_unigram  # noqa: F401
from .spec_engine import SpecConfig, generate  # noqa: F401
