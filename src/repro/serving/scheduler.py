"""Request scheduling: queueing, length-bucketing, batch formation, and the
slot map for continuous batching.

The engine's jitted generation requires a bounded set of prompt lengths (one
prefill shape per bucket keeps recompilation bounded); the scheduler pads
prompts up to the bucket boundary.  Static batching groups whole batches by
(bucket, max_new_tokens); continuous batching instead pops requests FIFO one
at a time (``pop_next``) and tracks which DecodeState slot each in-flight
request occupies (``SlotMap``), so rows can be admitted and retired between
verify calls.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..data.tokenizer import ByteTokenizer

_counter = itertools.count()


@dataclasses.dataclass
class Request:
    prompt: str
    max_new_tokens: int = 64
    eos_id: int = -1             # -1: never stop on eos
    # sampling controls (DESIGN.md §12): temperature 0 = greedy (bit-exact
    # spec path); > 0 samples losslessly through the same spec_step.
    # ``seed`` pins the request's rng key; None derives a deterministic key
    # from the engine seed and request_id (replayable either way).
    temperature: float = 0.0
    top_p: float = 1.0
    seed: Optional[int] = None
    request_id: int = dataclasses.field(default_factory=lambda: next(_counter))
    # filled on completion:
    output: Optional[str] = None
    output_ids: Optional[np.ndarray] = None
    stats: Optional[dict] = None


@dataclasses.dataclass
class Batch:
    requests: List[Request]
    tokens: np.ndarray           # (B, P) int32, right-padded to bucket
    max_new_tokens: int


DEFAULT_BUCKETS = (32, 64, 128, 256, 512)


def fit_bucket(n: int, buckets: Tuple[int, ...] = DEFAULT_BUCKETS) -> int:
    """Smallest bucket holding an n-token prompt (largest bucket clamps)."""
    for b in sorted(buckets):
        if n <= b:
            return b
    return max(buckets)


class Scheduler:
    """FIFO with length bucketing.

    ``align`` rounds every bucket boundary up to a multiple (the engine
    passes the TPU lane width when the Pallas backend is active, so prefill
    blocks and the cache lengths derived from the bucket ladder land on
    kernel-friendly tiles; 1 = keep the ladder as given).
    """

    def __init__(self, max_batch: int = 8,
                 buckets: Tuple[int, ...] = DEFAULT_BUCKETS,
                 align: int = 1):
        self.max_batch = max_batch
        self.align = max(1, align)
        self.buckets = tuple(sorted({-(-b // self.align) * self.align
                                     for b in buckets}))
        self.tok = ByteTokenizer()
        self._queue: List[Tuple[Request, List[int]]] = []

    def submit(self, req: Request) -> int:
        ids = self.tok.encode(req.prompt)
        self._queue.append((req, ids))
        return req.request_id

    def _bucket(self, n: int) -> int:
        return fit_bucket(n, self.buckets)

    def next_batch(self) -> Optional[Batch]:
        if not self._queue:
            return None
        groups: Dict[Tuple[int, int], List[Tuple[Request, List[int]]]] = \
            defaultdict(list)
        for req, ids in self._queue:
            key = (self._bucket(len(ids)), req.max_new_tokens)
            groups[key].append((req, ids))
        # take the largest group (best batching efficiency)
        key = max(groups, key=lambda k: len(groups[k]))
        chosen = groups[key][:self.max_batch]
        chosen_ids = {id(r) for r, _ in chosen}
        self._queue = [(r, i) for r, i in self._queue
                       if id(r) not in chosen_ids]
        bucket, mnt = key
        # LEFT-pad so that the last prompt token sits at position bucket-1:
        # the jitted engine prefills a uniform length and starts generating
        # from the final position of every row.  (Per-row pad masking inside
        # recurrent prefill is future work; BOS-padding keeps the shift tiny.)
        toks = np.stack([self.pad_to_bucket(ids) for _, ids in chosen])
        return Batch([r for r, _ in chosen], toks, mnt)

    def max_queued_bucket(self) -> Optional[int]:
        """Largest bucket any currently-queued prompt needs (None if idle).
        Lets the engine size its continuous DecodeState to the workload
        instead of the worst-case largest bucket."""
        if not self._queue:
            return None
        return max(self._bucket(len(ids)) for _, ids in self._queue)

    def pad_to_bucket(self, ids: List[int]) -> np.ndarray:
        """LEFT-pad ``ids`` with BOS so the last prompt token sits at position
        bucket-1 — identical placement to the static ``next_batch`` path, so
        both serving modes produce bit-identical outputs per request."""
        bucket = self._bucket(len(ids))
        toks = np.full((bucket,), self.tok.bos_id, np.int32)
        ids = ids[-bucket:]
        toks[bucket - len(ids):] = ids
        return toks

    def peek_next(self) -> Optional[Tuple[Request, np.ndarray, int]]:
        """FIFO head without popping: (request, (bucket,) int32, raw_len).

        Lets the engine decide admissibility (page reservation, prompt
        capacity) BEFORE committing to the pop — a deferred request stays at
        the head of the queue in order.  ``raw_len`` is the un-bucketed
        token count (diagnostics: rejection messages cite it alongside the
        bucket that actually gates admission).
        """
        if not self._queue:
            return None
        req, ids = self._queue[0]
        return req, self.pad_to_bucket(ids), len(ids)

    def pop_next(self) -> Optional[Tuple[Request, np.ndarray]]:
        """FIFO pop for continuous batching: (request, (bucket,) int32)."""
        if not self._queue:
            return None
        req, ids = self._queue.pop(0)
        return req, self.pad_to_bucket(ids)

    def pending(self) -> int:
        return len(self._queue)

    def queued_requests(self) -> List[Request]:
        """Snapshot of queued requests in FIFO order (no pop) — the engine
        inspects it at continuous-state build time to decide whether the
        step must compile the sampled verification walk."""
        return [r for r, _ in self._queue]


class SlotMap:
    """Which request occupies which DecodeState slot (continuous batching)."""

    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self._slots: List[Optional[Request]] = [None] * num_slots

    def __len__(self) -> int:
        return sum(r is not None for r in self._slots)

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self._slots) if r is None]

    def occupied(self) -> List[Tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self._slots) if r is not None]

    def get(self, slot: int) -> Optional[Request]:
        return self._slots[slot]

    def assign(self, slot: int, req: Request) -> None:
        if self._slots[slot] is not None:
            raise ValueError(f"slot {slot} already occupied by request "
                             f"{self._slots[slot].request_id}")
        self._slots[slot] = req

    def release(self, slot: int) -> Request:
        req = self._slots[slot]
        if req is None:
            raise ValueError(f"slot {slot} is already free")
        self._slots[slot] = None
        return req
