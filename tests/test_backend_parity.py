"""End-to-end backend parity: pallas (interpret) == xla, bit for bit.

The dispatch layer (kernels/dispatch.py) must be invisible in the outputs:
``generate()`` and the continuous ``ServingEngine.step()`` path produce
bit-identical tokens under ``backend="xla"`` and ``backend="pallas"``
(interpret mode on CPU), and both match ``greedy_reference`` — the paper's
lossless guarantee holds under every backend.  Also proves the kernels are
actually REACHED from the production entry points (no orphaned kernels) and
that a cache length that does not divide ``kernel_block_s`` exercises the
padding path correctly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.drafters import context_ngram_draft, match_hash_sweep
from repro.core.ngram_tables import NGramTables, build_bigram, build_unigram
from repro.core.spec_engine import (SpecConfig, generate, greedy_reference,
                                    init_decode_state, spec_step)
from repro.kernels import dispatch, ops
from repro.models import model as M
from repro.models.config import ModelConfig

F32 = dict(param_dtype=jnp.float32, compute_dtype=jnp.float32)


@pytest.fixture(scope="module")
def parity_model():
    """Tiny attention arch with a small kernel block so a handful of decode
    steps cross block boundaries (and interpret mode stays fast)."""
    cfg = ModelConfig(name="parity", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=61,
                      backend="xla", kernel_block_s=16, **F32).validate()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def parity_tables(parity_model):
    cfg, params = parity_model
    fwd = jax.jit(lambda t: M.forward(params, cfg, tokens=t)[0][:, -1])
    topk, chain = build_bigram(fwd, cfg.vocab_size, k_max=8, w_max=8,
                               batch=cfg.vocab_size)
    uni = build_unigram(params["embed"]["embedding"],
                        params["embed"]["lm_head"], k_max=8)
    return NGramTables(uni, topk, chain)


def _pallas(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, backend="pallas").validate()


# ---------------------------------------------------------------------------
# dispatch unit behaviour
# ---------------------------------------------------------------------------
def test_resolve_backend():
    assert dispatch.resolve_backend("xla") == "xla"
    assert dispatch.resolve_backend("pallas") == "pallas"
    on_tpu = jax.default_backend() == "tpu"
    assert dispatch.resolve_backend("auto") == ("pallas" if on_tpu else "xla")
    assert dispatch.default_interpret() == (not on_tpu)
    with pytest.raises(ValueError):
        dispatch.resolve_backend("cuda")


def test_align_cache_len_never_repads():
    for n, bs in [(1, 16), (15, 16), (16, 16), (17, 16), (96, 32),
                  (100, 32), (513, 0), (7, 0)]:
        a = dispatch.align_cache_len(n, bs)
        eff = bs or ops.DEFAULT_BLOCK_S
        assert a >= n
        # aligned length streams in whole blocks: no per-call repad
        assert a % min(eff, a) == 0


# ---------------------------------------------------------------------------
# drafter: sweep + scoring split
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("q,w", [(1, 3), (2, 2), (3, 4)])
def test_context_drafts_identical_backends(q, w):
    rng = np.random.default_rng(q * 10 + w)
    buf = jnp.asarray(rng.integers(0, 5, (3, 48)), jnp.int32)
    cur = jnp.asarray([40, 37, q], jnp.int32)   # incl. a cur_len < q+1 row
    dx, vx = context_ngram_draft(buf, cur, q, 4, w, backend="xla")
    dp, vp = context_ngram_draft(buf, cur, q, 4, w, backend="pallas")
    np.testing.assert_array_equal(np.asarray(vx), np.asarray(vp))
    # invalid rows carry unspecified tokens; compare where valid
    np.testing.assert_array_equal(
        np.asarray(jnp.where(vx[..., None], dx, 0)),
        np.asarray(jnp.where(vp[..., None], dp, 0)))


def test_sweep_identical_backends_nonmultiple_block():
    """L that does not divide block_l exercises the ngram padding path."""
    rng = np.random.default_rng(7)
    buf = jnp.asarray(rng.integers(0, 6, (2, 50)), jnp.int32)
    cur = jnp.asarray([50, 33], jnp.int32)
    q, w = 2, 3
    qx, mx, hx = match_hash_sweep(buf, cur, q, w, backend="xla")
    qp, mp, hp = match_hash_sweep(buf, cur, q, w, backend="pallas")
    np.testing.assert_array_equal(np.asarray(qx), np.asarray(qp))
    np.testing.assert_array_equal(np.asarray(mx), np.asarray(mp))
    np.testing.assert_array_equal(np.asarray(hx), np.asarray(hp))
    # padding path explicitly: block_l=32 on L=50 pads to 64
    m32, h32 = dispatch.ngram_sweep(buf, qx, cur, w=w, backend="pallas",
                                    block_l=32)
    np.testing.assert_array_equal(np.asarray(m32), np.asarray(mx))
    np.testing.assert_array_equal(np.asarray(h32), np.asarray(hx))


# ---------------------------------------------------------------------------
# end-to-end: generate() parity (and vs greedy_reference)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", ["context", "mixed"])
def test_generate_parity(parity_model, parity_tables, strategy):
    cfg, params = parity_model
    B, P, N = 2, 10, 16
    prompt = jax.random.randint(jax.random.PRNGKey(5), (B, P), 0,
                                cfg.vocab_size)
    ref = greedy_reference(params, cfg, prompt, N)
    spec_x = SpecConfig(k=3, w=3, q=1, strategy=strategy, max_new_tokens=N,
                        backend="xla")
    spec_p = dataclasses.replace(spec_x, backend="pallas")
    buf_x, len_x, _ = generate(params, cfg, spec_x, prompt, parity_tables)
    buf_p, len_p, _ = generate(params, _pallas(cfg), spec_p, prompt,
                               parity_tables)
    np.testing.assert_array_equal(np.asarray(len_x), np.asarray(len_p))
    # buffers may differ in length (pallas aligns the cache); tokens do not
    np.testing.assert_array_equal(np.asarray(buf_x[:, :P + N]),
                                  np.asarray(buf_p[:, :P + N]))
    np.testing.assert_array_equal(np.asarray(buf_p[:, :P + N]),
                                  np.asarray(ref))


def test_generate_parity_nonmultiple_cache(parity_model, parity_tables):
    """Cache length 41 with block_s 16: spec_attention_op pads to 48 and
    masks the phantom slots — tokens must still be bit-identical."""
    cfg, params = parity_model
    B, P, N = 2, 8, 12
    prompt = jax.random.randint(jax.random.PRNGKey(11), (B, P), 0,
                                cfg.vocab_size)
    outs = {}
    for backend in ("xla", "pallas"):
        c = dataclasses.replace(cfg, backend=backend).validate()
        spec = SpecConfig(k=3, w=3, strategy="mixed", max_new_tokens=N,
                          backend=backend)
        state = init_decode_state(params, c, spec, prompt, buf_size=41)
        for _ in range(64):
            if not bool(np.asarray(~state.done).any()):
                break
            state = spec_step(params, c, spec, state, parity_tables)
        outs[backend] = np.asarray(state.buf[:, :P + N])
        assert (np.asarray(state.buf_len) == P + N).all()
    np.testing.assert_array_equal(outs["xla"], outs["pallas"])
    ref = greedy_reference(params, cfg, prompt, N)
    np.testing.assert_array_equal(outs["pallas"], np.asarray(ref))


def test_generate_parity_hybrid_arch():
    """The kernel also runs inside the scanned heterogeneous stack (Jamba
    pattern: attention layer among recurrent mixers, gated replay commit)."""
    from repro.models.config import BlockSpec
    cfg = ModelConfig(
        name="hyb-parity", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=61,
        block_pattern=(BlockSpec("mamba", "swiglu"),
                       BlockSpec("attn", "swiglu")),
        backend="pallas", kernel_block_s=16, **F32).validate()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, P, N = 2, 8, 10
    prompt = jax.random.randint(jax.random.PRNGKey(5), (B, P), 0,
                                cfg.vocab_size)
    ref = greedy_reference(params, cfg, prompt, N)
    spec = SpecConfig(k=3, w=3, strategy="context", max_new_tokens=N,
                      backend="pallas")
    buf, _, _ = generate(params, cfg, spec, prompt, None)
    np.testing.assert_array_equal(np.asarray(buf[:, :P + N]),
                                  np.asarray(ref))


# ---------------------------------------------------------------------------
# kernels actually reached from the production entry points
# ---------------------------------------------------------------------------
def test_kernels_reached_from_generate(parity_model, parity_tables,
                                       monkeypatch):
    """No orphaned kernels: under backend="pallas" a fresh trace of the
    engine step must route through BOTH Pallas ops via the dispatch layer."""
    cfg, params = parity_model
    hits = {"attn": 0, "ngram": 0}
    real_attn, real_ngram = ops.spec_attention_op, ops.ngram_match_op

    def spy_attn(*a, **k):
        hits["attn"] += 1
        return real_attn(*a, **k)

    def spy_ngram(*a, **k):
        hits["ngram"] += 1
        return real_ngram(*a, **k)

    monkeypatch.setattr(ops, "spec_attention_op", spy_attn)
    monkeypatch.setattr(ops, "ngram_match_op", spy_ngram)
    cfg_p = dataclasses.replace(
        _pallas(cfg), name="parity-spy").validate()   # force a fresh trace
    spec = SpecConfig(k=3, w=3, strategy="context", max_new_tokens=6,
                      backend="pallas")
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size)
    generate(params, cfg_p, spec, prompt, parity_tables)
    assert hits["attn"] > 0, "spec_attention_op never dispatched"
    assert hits["ngram"] > 0, "ngram_match_op never dispatched"


# ---------------------------------------------------------------------------
# continuous serving step() parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", ["context", "mixed"])
def test_continuous_step_parity(parity_model, parity_tables, strategy):
    """The ServingEngine.step() path (admit -> spec_step -> retire) returns
    identical per-request outputs under both backends."""
    from repro.serving import ServingEngine
    cfg, params = parity_model
    spec = SpecConfig(k=3, w=3, strategy=strategy, max_new_tokens=12,
                      backend="xla")
    outs = {}
    for backend in ("xla", "pallas"):
        c = dataclasses.replace(cfg, backend=backend).validate()
        s = dataclasses.replace(spec, backend=backend)
        # bucket_align=1 keeps the prompt padding identical across
        # backends (lane-aligned buckets change the padded prompt itself,
        # which is a scheduling policy, not a numerics difference)
        eng = ServingEngine(params, c, s, tables=parity_tables, max_batch=2,
                            buckets=(16,), max_new_cap=12, bucket_align=1)
        r1 = eng.submit("backend parity", max_new_tokens=12)
        r2 = eng.submit("one step behind", max_new_tokens=7)
        eng.step()
        r3 = eng.submit("late arrival", max_new_tokens=9)
        done = eng.serve_continuous()
        assert sorted(r.request_id for r in done) == \
            sorted(r.request_id for r in (r1, r2, r3))
        outs[backend] = {r.prompt: np.asarray(r.output_ids) for r in done}
    assert outs["xla"].keys() == outs["pallas"].keys()
    for prompt in outs["xla"]:
        np.testing.assert_array_equal(outs["xla"][prompt],
                                      outs["pallas"][prompt],
                                      err_msg=prompt)
