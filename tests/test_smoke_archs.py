"""Per-assigned-architecture smoke tests (deliverable f).

Each arch instantiates its REDUCED family variant (<=2 layers, d_model<=512,
<=4 experts) and runs one forward + one train step + (decoders) one
speculative serve step on CPU, asserting shapes and finiteness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.models import model as M
from repro.train import AdamWConfig, init_train_state, make_train_step

pytestmark = pytest.mark.slow  # model-level suite; excluded from -m 'not slow' fast lane


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    ts = init_train_state(jax.random.PRNGKey(0), cfg)
    B, T = 2, 16
    if cfg.embedding_inputs:
        x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model))
        logits, _ = M.forward(ts["params"], cfg, embeds=x)
        assert logits.shape == (B, T, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        targets = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0,
                                     cfg.vocab_size)
        step = make_train_step(cfg, AdamWConfig(total_steps=2), remat=False)
        ts2, metrics = jax.jit(step)(ts, (x, targets))
        assert bool(jnp.isfinite(metrics["loss"]))
        return
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T + 1), 0,
                              cfg.vocab_size)
    logits, _ = M.forward(ts["params"], cfg, tokens=toks[:, :-1])
    assert logits.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    step = make_train_step(cfg, AdamWConfig(total_steps=2), remat=False)
    ts2, metrics = jax.jit(step)(ts, toks)
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually changed
    l0 = jax.tree_util.tree_leaves(ts["params"])[0]
    l1 = jax.tree_util.tree_leaves(ts2["params"])[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if not get_smoke_config(a).encoder_only])
def test_smoke_spec_serve_step(arch):
    """One prefill + one batched (k, w+1) verification + commit."""
    cfg = get_smoke_config(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, P, k, w1 = 2, 8, 3, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                              cfg.vocab_size)
    state = M.init_state(cfg, B, 32)
    logits, state = M.prefill(params, cfg, state, tokens=toks)
    assert bool(jnp.isfinite(logits).all())
    rows = jax.random.randint(jax.random.PRNGKey(2), (B, k, w1), 0,
                              cfg.vocab_size)
    vlogits, tails = M.verify(params, cfg, state, rows)
    assert vlogits.shape == (B, k, w1, cfg.vocab_size)
    assert bool(jnp.isfinite(vlogits).all())
    n = jnp.full((B,), 2, jnp.int32)
    if M.has_recurrent(cfg):
        _, state = M.decode(params, cfg, state, rows[:, 0], n_commit=n)
    else:
        state = M.commit_kv_tails(cfg, state, tails,
                                  jnp.zeros((B,), jnp.int32), n)
    assert int(state["cur_len"][0]) == P + 2
