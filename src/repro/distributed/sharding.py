"""Logical-axis sharding rules with divisibility fallbacks.

Scheme (DESIGN.md §6): 2D ("data", "model") per pod, + leading "pod" axis
multi-pod.
  - "embed"-like param dims  -> FSDP over ("pod","data")  (what lets
    Nemotron-340B / Jamba-398B fit v5e HBM),
  - "heads"/"ffn"/"kv"/"vocab"/"expert" dims -> tensor/expert parallel over
    "model",
  - activation batch         -> ("pod", "data"),
  - KV-cache: kv-heads over "model" when divisible, else head_dim;
    batch over ("pod","data") when divisible, else cache sequence over
    "data" (the batch=1 long-context case).

Every rule degrades to replication when the dim isn't divisible by the mesh
axis — a sharding that fails to lower is a bug, a replicated small tensor is
not.
"""
from __future__ import annotations

import contextlib
import warnings
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axes, in fallback order
_LOGICAL = {
    "embed": (("pod", "data"), ("data",)),
    "heads": (("model",),),
    "kv": (("model",),),
    "ffn": (("model",),),
    "vocab": (("model",),),
    "expert": (("model",),),
    None: (),
}


class ShardingFallbackWarning(UserWarning):
    """A logical axis degraded to replication because no mesh-axis chain
    divides the dim.  Correct but memory-costly: a mis-sized mesh serves
    the full replicated tensor on every device."""


# once-per-(logical, dim, mesh-shape) so traces don't spam; tests reset it
_FALLBACK_WARNED: set = set()
# scoped recorders (recording_fallbacks): every dead-end fallback is added
# to each active recorder, independent of the once-only warning dedup — so
# a caller (ServingEngine.mesh_report) can attribute fallbacks to ITS OWN
# spec resolution instead of reading the process-global history
_RECORDERS: List[Set[Tuple[str, int]]] = []


def _axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64))


def reset_fallback_warnings() -> None:
    _FALLBACK_WARNED.clear()


def fallback_report() -> List[Tuple[str, int]]:
    """(logical, dim) pairs that degraded to replication so far in this
    PROCESS (all meshes, all callers), sorted.  For a single engine's view
    use ``recording_fallbacks`` around its own spec resolution."""
    return sorted({(lg, d) for lg, d, _ in _FALLBACK_WARNED})


@contextlib.contextmanager
def recording_fallbacks():
    """Collect every replication dead-end hit while the context is active
    — repeats included (the once-only warning dedup does not apply), so
    re-resolving a spec tree always yields its full fallback set."""
    rec: Set[Tuple[str, int]] = set()
    _RECORDERS.append(rec)
    try:
        yield rec
    finally:
        # strictly LIFO — pop by position, not remove() (set equality
        # would match a different recorder with equal contents)
        assert _RECORDERS[-1] is rec
        _RECORDERS.pop()


def resolve_axis(mesh: Mesh, logical: Optional[str], dim: int, *,
                 warn: bool = True):
    """Pick the first fallback whose size divides ``dim`` (else None).

    Replication-on-non-divisible is by design (a sharding that fails to
    lower is a bug, a replicated tensor is not), but it must not be
    SILENT: when every candidate chain fails, a once-per-(axis, dim, mesh)
    ``ShardingFallbackWarning`` fires.  Callers that probe one rule only
    to fall back to ANOTHER sharding (e.g. the kv->sequence cache chain in
    ``state_pspec``) pass ``warn=False`` — there the tensor still ends up
    sharded and the warning would be a false alarm.
    """
    if logical is None:
        return None
    tried = False
    for axes in _LOGICAL[logical]:
        axes = tuple(a for a in axes if a in mesh.shape)
        if not axes:
            continue
        tried = True
        if dim % _axis_size(mesh, axes) == 0:
            return axes if len(axes) > 1 else axes[0]
    if tried and warn and dim > 1:     # replicating a size-1 dim is free
        for rec in _RECORDERS:
            rec.add((logical, dim))
        key = (logical, dim, tuple(sorted((str(k), int(v))
                                          for k, v in mesh.shape.items())))
        if key not in _FALLBACK_WARNED:
            _FALLBACK_WARNED.add(key)
            warnings.warn(
                f"logical axis {logical!r} (dim {dim}) divides no mesh axis "
                f"chain of {dict(mesh.shape)} — replicating (full per-device "
                f"memory).  Resize the mesh or the dim to shard it.",
                ShardingFallbackWarning, stacklevel=2)
    return None


def spec_for(mesh: Mesh, logicals: Tuple[Optional[str], ...],
             shape: Tuple[int, ...]) -> P:
    assert len(logicals) == len(shape), (logicals, shape)
    return P(*[resolve_axis(mesh, lg, d) for lg, d in zip(logicals, shape)])


# ----------------------------------------------------------------------------
# parameter rules, keyed by (parent, leaf-name)
# ----------------------------------------------------------------------------
_PARAM_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings
    "embedding": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    # norms
    "scale": (None,),
    "bias": (None,),
    # attention
    "wq": ("embed", "heads"),
    "wk": ("embed", "kv"),
    "wv": ("embed", "kv"),
    "wo": ("heads", "embed"),
    # dense mlps (and shared experts)
    "w_gate": ("embed", "ffn"),
    "w_up": ("embed", "ffn"),
    "w_down": ("ffn", "embed"),
    "shared_gate": ("embed", "ffn"),
    "shared_up": ("embed", "ffn"),
    "shared_down": ("ffn", "embed"),
    # moe (3D expert weights override the 2D mlp rules by rank below)
    "router": ("embed", None),
    # mamba
    "in_proj": ("embed", "ffn"),
    "conv_w": (None, "ffn"),
    "conv_b": ("ffn",),
    "x_proj": ("ffn", None),
    "dt_proj": (None, "ffn"),
    "dt_bias": ("ffn",),
    "A_log": ("ffn", None),
    "D": ("ffn",),
    "out_proj": ("ffn", "embed"),
    # mlstm
    "up_proj": ("embed", "ffn"),
    "w_if": (None, None),
    "b_i": (None,),
    "b_f": (None,),
    "gn_scale": (None,),
    "skip": (None,),
    "down_proj": ("ffn", "embed"),
    # slstm
    "w_in": ("embed", "ffn"),
    "r": (None, None, None, None),
    "b": (None,),
    "ffn_gate": ("embed", "ffn"),
    "ffn_up": ("embed", "ffn"),
    "ffn_down": ("ffn", "embed"),
}

_MOE_3D_RULES = {
    "w_gate": (("expert", "embed", None), (None, "embed", "ffn")),
    "w_up": (("expert", "embed", None), (None, "embed", "ffn")),
    "w_down": (("expert", None, "embed"), (None, "ffn", "embed")),
}


def _path_names(path) -> Tuple[str, ...]:
    """Key names along a tree path: dict keys, dataclass attribute names
    (registered dataclasses like DecodeState flatten to GetAttrKey) and
    sequence indices alike."""
    out = []
    for p in path:
        for attr in ("key", "name", "idx"):
            if hasattr(p, attr):
                out.append(str(getattr(p, attr)))
                break
        else:
            out.append(str(p))
    return tuple(out)


def param_pspec(mesh: Mesh, path, leaf) -> P:
    names = _path_names(path)
    name = names[-1]
    shape = tuple(leaf.shape)
    # body/prefix groups are stacked over periods: leading None
    stacked = any(n.startswith("p") and n[1:].isdigit()
                  or n.startswith("pre") for n in names)
    core_shape = shape[1:] if stacked else shape
    if name in _MOE_3D_RULES and len(core_shape) == 3:
        for rule in _MOE_3D_RULES[name]:
            # probe silently (the next rule is the fallback)...
            spec = [resolve_axis(mesh, lg, d, warn=False)
                    for lg, d in zip(rule, core_shape)]
            if spec[0] is not None or rule[0] is None:
                break
        # falls through to the last rule if the expert dim never divided.
        # ...then re-resolve the CHOSEN rule loudly: its dead ends (any
        # dim, not just the leading one) are genuine replication
        spec = [resolve_axis(mesh, lg, d) for lg, d in zip(rule, core_shape)]
    elif name in _PARAM_RULES and len(_PARAM_RULES[name]) == len(core_shape):
        rule = _PARAM_RULES[name]
        spec = [resolve_axis(mesh, lg, d) for lg, d in zip(rule, core_shape)]
    else:
        spec = [None] * len(core_shape)
    if stacked:
        spec = [None] + spec
    return P(*spec)


def params_shardings(mesh: Mesh, params_shapes) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_pspec(mesh, path, leaf)),
        params_shapes)


# ----------------------------------------------------------------------------
# decode-state rules
# ----------------------------------------------------------------------------
def _batch_axes(mesh: Mesh, b: int):
    # batch/slot dims are transient and cheap: an odd batch (a 3-prompt
    # partial batch, an odd slot count) replicating is routine, not the
    # mis-sized-mesh memory hazard the fallback warning flags
    return resolve_axis(mesh, "embed", b, warn=False)


def state_pspec(mesh: Mesh, path, leaf) -> P:
    names = _path_names(path)
    name = names[-1]
    shape = tuple(leaf.shape)
    if name == "cur_len":
        return P(None)
    R, B = shape[0], shape[1]
    batch = _batch_axes(mesh, B)
    if name in ("k", "v"):                      # (R, B, S, KV, hd)
        _, _, S, KV, hd = shape
        kv_ax = resolve_axis(mesh, "kv", KV, warn=False)   # seq fallback below
        seq_ax = None
        if kv_ax is None and S % mesh.shape.get("model", 1) == 0:
            # kv heads don't divide the model axis (kv=8/2/1 GQA): shard the
            # cache SEQUENCE over "model" instead — attention contracts hd
            # (replicated) and softmaxes over the sharded seq with small
            # partial-reduce collectives.  Sharding hd instead forces an
            # all-reduce of full (.., S) logits per layer (§Perf it-5).
            seq_ax = "model"
        if batch is None and seq_ax is None:
            # batch=1 long-context: shard the cache sequence over "data"
            seq_ax = "data" if S % mesh.shape.get("data", 1) == 0 else None
        return P(None, batch, seq_ax, kv_ax, None)
    if name == "conv":                          # (R, B, dc-1, di)
        return P(None, batch, None, resolve_axis(mesh, "ffn", shape[-1]))
    if name == "ssm":                           # (R, B, di, ds)
        return P(None, batch, resolve_axis(mesh, "ffn", shape[2]), None)
    if name == "C":                             # (R, B, nh, dh, dh)
        nh_ax = resolve_axis(mesh, "heads", shape[2], warn=False)
        dh_ax = resolve_axis(mesh, "heads", shape[3]) if nh_ax is None \
            else None
        return P(None, batch, nh_ax, dh_ax, None)
    if name in ("n", "h", "c", "m"):            # (R,B,nh[,dh])
        nh_ax = resolve_axis(mesh, "heads", shape[2], warn=False)
        rest = [None] * (len(shape) - 3)
        if nh_ax is None and len(shape) > 3:
            rest[0] = resolve_axis(mesh, "heads", shape[3])
        return P(None, batch, nh_ax, *rest)
    return P(*([None] * len(shape)))


def state_shardings(mesh: Mesh, state_shapes) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, state_pspec(mesh, path, leaf)),
        state_shapes)


# ----------------------------------------------------------------------------
# full DecodeState rules (live sharded serving, DESIGN.md §10)
# ----------------------------------------------------------------------------
# per-slot row leaves of core.spec_engine.DecodeState: dim 0 is the slot
# ("batch") axis; everything trailing is replicated.  The sampling leaves
# (rng_key (B, 2), temperature/top_p (B,)) are ordinary per-slot rows: the
# in-step key split/gumbel draws are row-local, so they shard with their
# slot exactly like the bandit stats.
_STATE_ROW_FIELDS = ("buf", "buf_len", "prompt_len", "budget", "eos_id",
                     "done", "active", "rng_key", "temperature", "top_p")

# The single source of truth for WHICH DecodeState leaves have a sharding
# rule — ``decode_state_pspec(strict=True)`` raises KeyError for any leaf
# matching no entry, and repro-lint's sharding-coverage analyzer runs
# strict over every registry config (so adding a DecodeState leaf without
# extending this table fails CI instead of silently replicating — the
# PR-7 rng_key/temperature/top_p class).  Top-level fields match on the
# path HEAD; model-cache leaves match on the path TAIL (they sit under
# ``model``, arbitrarily nested per layer).
DECODE_STATE_LEAF_RULES: Dict[str, str] = {
    # --- top-level per-slot rows (match on path head) ---
    **{f: "per-slot row: slot axis over ('pod','data'), rest replicated"
       for f in _STATE_ROW_FIELDS},
    "stats": "telemetry rows: slot axis over ('pod','data')",
    # --- model-cache leaves (match on path tail, under `model`) ---
    "cur_len": "scalar step counter: replicated",
    "k": "KV cache: kv-heads over 'model' else sequence fallback; "
         "paged pool: page axis over ('pod','data')[+'model']",
    "v": "same rule as 'k'",
    "conv": "mamba conv window: channel dim over 'ffn'->'model'",
    "ssm": "mamba ssm state: inner dim over 'ffn'->'model'",
    "C": "mlstm covariance: heads over 'model' else head_dim",
    "n": "mlstm/slstm normalizer: heads over 'model'",
    "h": "slstm hidden: heads over 'model'",
    "c": "slstm cell: heads over 'model'",
    "m": "mlstm/slstm max-stabilizer: heads over 'model'",
    "page_table": "per-slot page map: slot axis over ('pod','data')",
    "n_pages": "per-slot page count: slot axis over ('pod','data')",
    "free_list": "free-page stack: replicated (device-identical mutation)",
    "free_top": "free-stack pointer: replicated",
}


def _page_axes(mesh: Mesh, num_pages: int, kv_sharded: bool):
    """The paged pool's page axis shards like the linear cache's
    (batch, sequence) pair it replaces: capacity-parallel over
    ("pod","data") when divisible, extended over "model" too when the kv
    heads could not take the model axis (the GQA kv=8/2/1 case — exactly
    the linear layout's sequence-over-"model" fallback)."""
    axes: Tuple[str, ...] = ()
    for chain in (("pod", "data"), ("data",)):
        c = tuple(a for a in chain if a in mesh.shape)
        if c and num_pages % _axis_size(mesh, c) == 0:
            axes = c
            break
    if not kv_sharded and "model" in mesh.shape:
        cand = axes + ("model",)
        if num_pages % _axis_size(mesh, cand) == 0:
            axes = cand
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def decode_state_pspec(mesh: Mesh, path, leaf, *, paged: bool = False,
                       strict: bool = False) -> P:
    """PartitionSpec for ONE leaf of a full ``DecodeState`` pytree.

    Extends ``state_pspec`` (which covers the model-cache leaves) with the
    serving-level leaves: the token buffer / per-slot scalars / stats rows
    shard their slot axis over ("pod","data"); the paged pool's page axis
    shards like the sequence axis (ROADMAP); page tables are slot-sharded
    and the free stack is replicated (it is mutated identically on every
    device — a tiny int32 vector, and replication keeps alloc/free/grow
    collective-free).

    ``strict=True`` raises ``KeyError`` for a leaf matching no
    ``DECODE_STATE_LEAF_RULES`` entry instead of silently replicating it —
    the mode repro-lint's sharding-coverage analyzer runs in.  The engine
    itself stays non-strict: at serve time a replicated unknown leaf is
    correct (just unreviewed), and the lint gate is where the review is
    forced.
    """
    names = _path_names(path)
    top, name = names[0], names[-1]
    if strict and top not in DECODE_STATE_LEAF_RULES \
            and name not in DECODE_STATE_LEAF_RULES:
        raise KeyError(
            f"DecodeState leaf {'/'.join(names)!r} matches no "
            f"DECODE_STATE_LEAF_RULES entry — add one (plus a pspec branch "
            f"if it needs more than replication/slot-row sharding)")
    shape = tuple(leaf.shape)
    if top in _STATE_ROW_FIELDS or top == "stats":
        return P(_batch_axes(mesh, shape[0]), *([None] * (len(shape) - 1)))
    # below here: the model-cache subtree
    if name == "page_table":
        return P(_batch_axes(mesh, shape[0]), None)
    if name == "n_pages":
        return P(_batch_axes(mesh, shape[0]))
    if name in ("free_list", "free_top"):
        return P(*([None] * len(shape)))
    if paged and name in ("k", "v"):            # pool (R, NP, ps, KV, hd)
        _, NP, _, KV, _ = shape
        kv_ax = resolve_axis(mesh, "kv", KV, warn=False)
        page_ax = _page_axes(mesh, NP, kv_sharded=kv_ax is not None)
        if kv_ax is None and page_ax is None:
            resolve_axis(mesh, "kv", KV)        # end of chain: warn once
        return P(None, page_ax, None, kv_ax, None)
    return state_pspec(mesh, path, leaf)


def decode_state_shardings(mesh: Mesh, state, *, strict: bool = False) -> Any:
    """NamedSharding pytree for a ``DecodeState`` (or shape structs of one).

    Detects the paged layout from the state itself ("page_table" under
    ``model``), so callers pass the state they actually built.  ``strict``
    is forwarded to ``decode_state_pspec``.
    """
    paged = isinstance(getattr(state, "model", None), dict) \
        and "page_table" in state.model
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, decode_state_pspec(mesh, path, leaf, paged=paged,
                                     strict=strict)),
        state)


def spec_summary(shardings) -> Dict[str, str]:
    """{leaf path: partition spec} for a NamedSharding pytree — the
    human-readable half of ``ServingEngine.mesh_report()``."""
    flat = jax.tree_util.tree_flatten_with_path(shardings)[0]
    return {"/".join(_path_names(path)): str(tuple(sh.spec))
            for path, sh in flat}


def batch_sharding(mesh: Mesh, shape: Tuple[int, ...],
                   batch_dim: int = 0) -> NamedSharding:
    """Tokens / embeds / logits: batch over ("pod","data"), rest replicated.

    Exception: (3, B, T) M-RoPE positions -> batch_dim=1.
    """
    spec = [None] * len(shape)
    spec[batch_dim] = _batch_axes(mesh, shape[batch_dim])
    return NamedSharding(mesh, P(*spec))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
