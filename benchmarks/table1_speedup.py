"""Table 1 reproduction: tokens/call + speedup per (model size, task).

Two tiny trained models stand in for the paper's {Phi3B, Mistral7B,
Vicuna13B}; for each task we report the default (10, 10) strategy and the
best (k*, w*) from a small sweep — tokens/call measured, wall-time speedup
modeled on TPU v5e via the roofline call-cost (and CPU wall-time speedup
vs the greedy engine as a secondary, noisy, signal).
"""
from __future__ import annotations

import csv
import os

from repro.configs import get_config
from repro.core.phase import slowdown
from repro.core.spec_engine import SpecConfig

from .common import (SIZES, TASKS, ensure_dirs, get_tables, get_trained,
                     measure)

SWEEP = [(10, 10), (5, 4), (10, 4), (25, 2), (5, 10)]


def run(out_dir: str = "experiments/results", max_new: int = 48) -> dict:
    ensure_dirs()
    target = get_config("mistral-7b")
    path = os.path.join(out_dir, "table1_speedup.csv")
    rows = []
    with open(path, "w", newline="") as f:
        wr = csv.writer(f)
        wr.writerow(["model", "task", "strategy", "k", "w",
                     "tokens_per_call", "modeled_speedup_v5e",
                     "cpu_speedup_vs_greedy"])
        for size in SIZES:
            cfg, params = get_trained(size)
            tables = get_tables(cfg, params)
            for task in TASKS:
                greedy = measure(cfg, params, tables, task,
                                 SpecConfig(strategy="greedy",
                                            max_new_tokens=max_new),
                                 n_prompts=4)
                results = {}
                for (k, w) in SWEEP:
                    spec = SpecConfig(k=k, w=w, strategy="mixed",
                                      max_new_tokens=max_new)
                    r = measure(cfg, params, tables, task, spec, n_prompts=4)
                    sp = r.tokens_per_call / slowdown(target, 512, k, w)
                    cpu_sp = greedy.wall_s / max(r.wall_s, 1e-9)
                    results[(k, w)] = (r.tokens_per_call, sp, cpu_sp)
                # default row + best row (by modeled speedup)
                for label, kw in (("default", (10, 10)),
                                  ("best", max(results,
                                               key=lambda x: results[x][1]))):
                    tpc, sp, cpu_sp = results[kw]
                    wr.writerow([size, task, label, kw[0], kw[1],
                                 f"{tpc:.3f}", f"{sp:.3f}", f"{cpu_sp:.3f}"])
                    rows.append((size, task, label, kw, tpc, sp, cpu_sp))
    return {"csv": path, "rows": rows}


def main():
    res = run()
    print("table1_speedup ->", res["csv"])
    for size, task, label, kw, tpc, sp, cpu_sp in res["rows"]:
        print(f"  {size:9s} {task:5s} {label:7s} (k,w)={kw}: "
              f"tok/call={tpc:.2f} v5e-speedup={sp:.2f}x cpu={cpu_sp:.2f}x")


if __name__ == "__main__":
    main()
