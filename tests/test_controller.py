"""Adaptive (k, w) controller: converges to the best speedup arm.

Covers both implementations of the scoring rule: the host-side per-batch
``AdaptiveKW`` and the vectorized per-slot bandit (``init_arm_stats`` /
``choose_arms`` / ``update_arm_stats``) that runs inside the jitted
spec_step — including slot-reset on release/admit reuse, per-slot
independence (no cross-slot reward leakage) and convergence to a planted
best arm per slot.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controller import (AdaptiveKW, arm_slowdowns, choose_arms,
                                   init_arm_stats, update_arm_stats)
from repro.models.config import ModelConfig


def _cfg():
    return ModelConfig(name="c", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=2, d_ff=128, vocab_size=61).validate()


def test_controller_explores_all_arms_first():
    c = AdaptiveKW(_cfg())
    seen = set()
    for _ in range(len(c.arms)):
        a = c.choose()
        assert a not in seen           # inf bonus forces one pull each
        seen.add(a)
        c.update(a, tokens=10, calls=10)
    assert seen == set(c.arms)


def test_controller_converges_to_best_ratio():
    rng = np.random.default_rng(0)
    c = AdaptiveKW(_cfg(), explore=0.05)
    # synthetic environment: acceptance grows with w but saturates; the
    # roofline slowdown makes huge (k,w) not worth it
    true_tpc = {(1, 0): 1.0, (5, 4): 2.0, (10, 4): 2.2, (10, 10): 2.6,
                (25, 2): 1.8}
    for _ in range(300):
        a = c.choose()
        tok = true_tpc[a] * 10 * (1 + 0.05 * rng.standard_normal())
        c.update(a, tokens=tok, calls=10)
    best = c.best_exploit()
    ratios = {a: true_tpc[a] / c.slow[a] for a in c.arms}
    assert best == max(ratios, key=ratios.get)


def test_controller_slowdown_prior_sane():
    c = AdaptiveKW(_cfg())
    assert c.slow[(1, 0)] == 1.0
    assert c.slow[(25, 2)] >= c.slow[(5, 4)] * 0.5  # monotone-ish in cost
    assert all(v >= 1.0 for v in c.slow.values())


# ---------------------------------------------------------------------------
# vectorized per-slot bandit (runs inside the jitted spec_step)
# ---------------------------------------------------------------------------
ARMS = ((1, 0), (4, 2), (8, 4))


def _slow():
    return arm_slowdowns(_cfg(), ARMS)


def test_vectorized_matches_host_slowdowns():
    """Both bandit implementations must score against the same roofline
    prior."""
    host = AdaptiveKW(_cfg(), arms=ARMS)
    np.testing.assert_allclose(np.asarray(_slow()),
                               [host.slow[a] for a in ARMS])


def test_vectorized_explores_all_arms_first_per_slot():
    """Unpulled arms are pulled first, in index order, independently per
    slot (AdaptiveKW's infinite-bonus behaviour, vectorized)."""
    B = 3
    stats = init_arm_stats(B, len(ARMS))
    slow = _slow()
    seen = [[] for _ in range(B)]
    rng = np.random.default_rng(0)
    for _ in range(len(ARMS)):
        arm = choose_arms(stats, slow)
        for b in range(B):
            assert int(arm[b]) not in seen[b]
            seen[b].append(int(arm[b]))
        stats = update_arm_stats(
            stats, arm, jnp.asarray(rng.uniform(1, 5, B), jnp.float32),
            jnp.ones((B,), bool))
    for b in range(B):
        assert sorted(seen[b]) == list(range(len(ARMS)))


def test_vectorized_no_cross_slot_leakage():
    """Updating slot 0 must not move slot 1's stats or change its choice."""
    stats = init_arm_stats(2, len(ARMS))
    slow = _slow()
    # pull every arm once on both slots so choices are reward-driven
    for a in range(len(ARMS)):
        arm = jnp.asarray([a, a], jnp.int32)
        stats = update_arm_stats(stats, arm, jnp.asarray([1.0, 1.0]),
                                 jnp.ones((2,), bool))
    before = {k: np.asarray(v).copy() for k, v in stats.items()}
    choice1_before = int(choose_arms(stats, slow)[1])
    # hammer slot 0 with a huge reward for arm 2; slot 1 is inactive
    for _ in range(10):
        stats = update_arm_stats(stats, jnp.asarray([2, 0], jnp.int32),
                                 jnp.asarray([50.0, 99.0]),
                                 jnp.asarray([True, False]))
    for k in before:
        np.testing.assert_array_equal(np.asarray(stats[k])[1],
                                      before[k][1],
                                      err_msg=f"slot 1 {k} leaked")
    assert int(choose_arms(stats, slow)[1]) == choice1_before
    assert int(choose_arms(stats, slow)[0]) == 2


def test_vectorized_converges_to_planted_arm_per_slot():
    """Seeded synthetic rewards with a DIFFERENT planted best arm per slot:
    each slot's pull distribution must concentrate on its own arm."""
    rng = np.random.default_rng(42)
    B = len(ARMS)
    slow = np.asarray(_slow())
    # plant arm b as best for slot b: reward ~= slow * (1.5 + noise) for
    # the planted arm (score ~1.5), ~= slow * 0.5 for the rest
    stats = init_arm_stats(B, len(ARMS))
    for _ in range(300):
        arm = choose_arms(stats, _slow(), explore=0.05)
        a = np.asarray(arm)
        planted = (a == np.arange(B))
        reward = slow[a] * np.where(planted, 1.5, 0.5) \
            * (1 + 0.05 * rng.standard_normal(B))
        stats = update_arm_stats(stats, arm,
                                 jnp.asarray(reward, jnp.float32),
                                 jnp.ones((B,), bool))
    pulls = np.asarray(stats["arm_pulls"])
    assert (pulls.argmax(axis=1) == np.arange(B)).all(), pulls
    # decisive, not marginal: the planted arm dominates each slot's pulls
    assert (pulls[np.arange(B), np.arange(B)] > 0.6 * pulls.sum(1)).all()


def test_arm_stats_reset_on_slot_reuse():
    """release_slot and admit_slot both zero a slot's bandit rows inside
    the donated jits — a reused slot cannot inherit rewards."""
    from repro.core.spec_engine import (SpecConfig, admit_slot,
                                        empty_decode_state, release_slot)
    from repro.models import model as M
    cfg = ModelConfig(name="c-reset", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=31,
                      param_dtype=jnp.float32,
                      compute_dtype=jnp.float32).validate()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    spec = SpecConfig(k=4, w=2, strategy="mixed", max_new_tokens=8,
                      arms=((1, 0), (4, 2)))
    state = empty_decode_state(cfg, spec, 2, 32)
    # fake a history on both slots
    dirty = update_arm_stats(
        {k: state.stats[k] for k in ("arm_pulls", "arm_reward", "arm_last")},
        jnp.asarray([1, 1], jnp.int32), jnp.asarray([3.0, 3.0]),
        jnp.ones((2,), bool))
    import dataclasses
    state = dataclasses.replace(state, stats={**state.stats, **dirty})
    assert int(np.asarray(state.stats["arm_pulls"]).sum()) == 2
    state = release_slot(state, jnp.int32(0))
    assert np.asarray(state.stats["arm_pulls"])[0].sum() == 0
    assert np.asarray(state.stats["arm_reward"])[0].sum() == 0
    assert np.asarray(state.stats["arm_pulls"])[1].sum() == 1  # untouched
    prompt = jnp.asarray(np.arange(6) % 31, jnp.int32)
    state = admit_slot(params, cfg, state, jnp.int32(1), prompt,
                       jnp.int32(4), jnp.int32(-1))
    assert np.asarray(state.stats["arm_pulls"])[1].sum() == 0
    assert np.asarray(state.stats["arm_reward"])[1].sum() == 0
