"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) pair, lower + compile the production
step function on the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh,
print memory/cost analyses, extract collective bytes from the optimized
HLO, and persist everything to experiments/dryrun/*.json for the roofline
report (benchmarks/roofline.py).

Usage:
  python -m repro.launch.dryrun --arch mistral-7b --shape decode_32k
  python -m repro.launch.dryrun --all                  # full 40-pair matrix
  python -m repro.launch.dryrun --all --multi-pod
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape decode_32k --spec
"""
import os

if __name__ == "__main__":
    # 512-placeholder-device override, entry-point ONLY: it must precede
    # the jax import below (the device count locks on first init), and
    # IMPORTING this module (test collection, benchmarks borrowing
    # collective_bytes) must never mutate jax device state.  The append-
    # don't-clobber / respect-caller-count policy lives in hostdev.
    from repro.launch.hostdev import ensure_host_devices
    ensure_host_devices(512)

import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS  # noqa: E402
from repro.launch.input_specs import SHAPES, resolve_case  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op (per-device program)."""
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for c in _COLLECTIVES:
            # match ` = <type> op-name(` incl. async `-start` variants
            m = re.search(rf"=\s+(.*?)\s+{c}(?:-start)?\(", line)
            if m:
                out[c] += _type_bytes(m.group(1))
                counts[c] += 1
                break
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    out["counts"] = counts
    return out


def _mem_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend without memory analysis
        return {"error": str(e)}
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes"]
    d = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            d[k] = int(v)
    d["total_hbm_bytes"] = (d.get("argument_size_in_bytes", 0)
                            + d.get("output_size_in_bytes", 0)
                            + d.get("temp_size_in_bytes", 0)
                            - d.get("alias_size_in_bytes", 0))
    return d


def _compile_case(case, mesh):
    from repro.distributed import act_sharding
    jfn = jax.jit(case.fn, in_shardings=case.in_shardings,
                  out_shardings=case.out_shardings,
                  donate_argnums=case.donate)
    with act_sharding.activated(mesh), mesh:
        lowered = jfn.lower(*case.args)
        compiled = lowered.compile()
    return compiled


def _cost_dict(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    return {k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float)) and (
                k in ("flops", "bytes accessed", "transcendentals")
                or k.startswith("bytes accessed"))}


def run_case(arch: str, shape: str, multi_pod: bool,
             spec_step: bool = False, roofline: bool = False) -> dict:
    from repro.configs import get_config
    from repro.models import runtime_flags
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
           "spec_step": spec_step, "n_devices": 512 if multi_pod else 256}
    mesh = make_production_mesh(multi_pod=multi_pod)
    case = resolve_case(arch, shape, mesh, spec_step=spec_step)
    if case.skip_reason:
        rec["status"] = "skip"
        rec["skip_reason"] = case.skip_reason
        return rec
    t0 = time.time()
    compiled = _compile_case(case, mesh)
    rec["compile_s"] = round(time.time() - t0, 1)
    rec["cost"] = _cost_dict(compiled)
    rec["memory"] = _mem_dict(compiled)
    rec["collectives"] = collective_bytes(compiled.as_text())
    rec["status"] = "ok"

    if roofline:
        # Calibration: compile 1-period and 2-period variants with all scans
        # unrolled (exact HloCostAnalysis), extrapolate linearly in depth.
        cfg = get_config(arch)
        P, pre = cfg.pattern_period, len(cfg.prefix_blocks)
        L1, L2 = pre + P, pre + 2 * P
        calib = {"pattern_period": P, "prefix_layers": pre,
                 "full_layers": cfg.num_layers}
        runtime_flags.set_unroll(True)
        try:
            for tag, L in (("L1", L1), ("L2", L2)):
                c = resolve_case(arch, shape, mesh, spec_step=spec_step,
                                 num_layers=L)
                t0 = time.time()
                comp = _compile_case(c, mesh)
                calib[tag] = {"layers": L, "cost": _cost_dict(comp),
                              "collectives": collective_bytes(comp.as_text()),
                              "compile_s": round(time.time() - t0, 1)}
        finally:
            runtime_flags.set_unroll(False)
        rec["calib"] = calib
    return rec


def _drive_subprocesses(cases, args, timeout_s: int = 2400) -> None:
    """Run each case in an isolated subprocess: one pathological compile
    must not take down the rest of the matrix.  Caches finished cases."""
    import subprocess
    import sys
    os.makedirs(args.out, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for arch, shape in cases:
        tag = "spec" if args.spec else "base"
        mesh_tag = "multipod" if args.multi_pod else "pod"
        fname = os.path.join(args.out,
                             f"{arch}__{shape}__{mesh_tag}__{tag}.json")
        if os.path.exists(fname):
            rec = json.load(open(fname))
            st = rec.get("status")
            calib_ok = (not args.roofline) or ("calib" in rec) \
                or st != "ok"
            if st in ("ok", "skip") and calib_ok:
                print(f"[cache] {arch:22s} {shape:12s} ({st})", flush=True)
                n_ok += st == "ok"
                n_skip += st == "skip"
                continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", args.out]
        for flag, on in (("--multi-pod", args.multi_pod),
                         ("--spec", args.spec),
                         ("--roofline", args.roofline)):
            if on:
                cmd.append(flag)
        err = ""
        try:
            r = subprocess.run(cmd, timeout=timeout_s,
                               capture_output=True, text=True)
            if r.returncode:
                err = (r.stdout[-400:] + r.stderr[-400:])
        except subprocess.TimeoutExpired:
            err = f"calibration timeout after {timeout_s}s"
        # the subprocess writes the base record BEFORE calibration: keep a
        # good base record even if calibration timed out / crashed
        if os.path.exists(fname):
            st = json.load(open(fname)).get("status", "fail")
            if st == "ok" and err:
                err = f"(base ok; {err})"
        else:
            st = "fail"
            with open(fname, "w") as f:
                json.dump({"arch": arch, "shape": shape, "status": "fail",
                           "error": err or "no output"}, f, indent=1)
        n_ok += st == "ok"
        n_skip += st == "skip"
        n_fail += st == "fail"
        print(f"[{st:4s}] {arch:22s} {shape:12s} {err[:120]}", flush=True)
    print(f"done: {n_ok} ok, {n_skip} skip, {n_fail} fail")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true",
                    help="run the full assigned 10x4 matrix")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--spec", action="store_true",
                    help="lower the speculative (k,w+1) serve step instead "
                         "of the 1-token baseline")
    ap.add_argument("--roofline", action="store_true",
                    help="add unrolled 1/2-period calibration compiles for "
                         "exact per-layer cost extrapolation")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    if args.all:
        # cheap decode shapes first (bank results), train last
        order = ["decode_32k", "long_500k", "prefill_32k", "train_4k"]
        cases = [(a, s) for s in order for a in ASSIGNED_ARCHS]
        _drive_subprocesses(cases, args)
        return
    assert args.arch and args.shape, "--arch/--shape or --all"
    cases = [(args.arch, args.shape)]

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for arch, shape in cases:
        tag = "spec" if args.spec else "base"
        mesh_tag = "multipod" if args.multi_pod else "pod"
        fname = os.path.join(args.out,
                             f"{arch}__{shape}__{mesh_tag}__{tag}.json")
        try:
            # write the base record BEFORE calibration so a slow/killed
            # calibration never loses the lower+compile proof
            rec = run_case(arch, shape, args.multi_pod, spec_step=args.spec,
                           roofline=False)
            with open(fname, "w") as f:
                json.dump(rec, f, indent=1)
            if args.roofline and rec["status"] == "ok":
                rec = run_case(arch, shape, args.multi_pod,
                               spec_step=args.spec, roofline=True)
        except Exception:
            rec = {"arch": arch, "shape": shape, "status": "fail",
                   "error": traceback.format_exc()[-2000:]}
        with open(fname, "w") as f:
            json.dump(rec, f, indent=1)
        st = rec["status"]
        n_ok += st == "ok"
        n_skip += st == "skip"
        n_fail += st == "fail"
        extra = ""
        if st == "ok":
            extra = (f"flops/dev={rec['cost'].get('flops', 0):.3g} "
                     f"hbm/dev={rec['memory'].get('total_hbm_bytes', 0)/2**30:.2f}GiB "
                     f"coll/dev={rec['collectives']['total']/2**20:.1f}MiB "
                     f"compile={rec['compile_s']}s")
        elif st == "skip":
            extra = rec["skip_reason"]
        else:
            extra = rec["error"].splitlines()[-1][:160]
        print(f"[{st:4s}] {arch:22s} {shape:12s} {extra}", flush=True)
    print(f"done: {n_ok} ok, {n_skip} skip, {n_fail} fail")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
