"""The speculative generation engine: draft -> verify -> accept -> commit.

Unlike the paper's Python decode loop, the whole generation is ONE jitted
``lax.while_loop`` with fixed shapes (a requirement for TPU serving): the
token buffer is static-length, per-sequence progress is tracked by
``cur_len``, and finished rows simply commit 0 tokens.

Invariants:
  - output is bit-identical to greedy decoding (property-tested);
  - state.cur_len == #cached positions == buf_len - 1 (the last committed
    token's KV is materialised by the *next* call, exactly as in the paper's
    Appendix D cache).

Commit paths:
  - attention-only archs: write the winner's verified KV tail (no extra
    model call) — ``commit_kv_tails``;
  - archs with recurrent mixers (Jamba, xLSTM): gated replay of the winner
    row (one (B, w+1) forward; ~1/k of the verify cost) — see DESIGN.md §4.

Statistics mirror the paper's ablations (Fig. 4): acceptance-length
histogram, winning-rank histogram, context/bigram allocation and
per-strategy accepted tokens.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import model as M
from ..models.config import ModelConfig
from .drafters import (bigram_draft, context_ngram_draft, mixed_draft,
                       unigram_draft)
from .ngram_tables import NGramTables
from .verify import accept


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    k: int = 10                 # number of batched drafts
    w: int = 10                 # speculation depth
    q: int = 1                  # context-match query length
    strategy: str = "mixed"     # mixed | bigram | unigram | context | greedy
    max_new_tokens: int = 64
    eos_id: int = -1            # -1: never stop on eos


def _draft(spec: SpecConfig, tables: NGramTables, buf, buf_len, last):
    if spec.strategy == "mixed":
        return mixed_draft(tables, buf, buf_len, last, spec.q, spec.k, spec.w)
    if spec.strategy == "bigram":
        d, v = bigram_draft(tables, last, spec.k, spec.w)
    elif spec.strategy == "unigram":
        d, v = unigram_draft(tables, buf.shape[0], spec.k, spec.w)
    elif spec.strategy == "context":
        d, v = context_ngram_draft(buf, buf_len, spec.q, spec.k, spec.w)
        d = jnp.where(v[..., None], d, 0)
    else:
        raise ValueError(spec.strategy)
    n_ctx = (v.sum(axis=1) if spec.strategy == "context"
             else jnp.zeros((buf.shape[0],), jnp.int32))
    return d, v, n_ctx.astype(jnp.int32)


def _init_stats(spec: SpecConfig, B: int) -> Dict[str, jnp.ndarray]:
    return {
        "calls": jnp.zeros((B,), jnp.int32),
        "tokens": jnp.zeros((B,), jnp.int32),
        "accept_hist": jnp.zeros((B, spec.w + 2), jnp.int32),   # n_commit 0..w+1
        "rank_hist": jnp.zeros((B, max(spec.k, 1)), jnp.int32),
        "alloc_ctx": jnp.zeros((B, spec.k + 1), jnp.int32),     # n_ctx per call
        "accepted_ctx": jnp.zeros((B,), jnp.int32),             # drafted tokens
        "accepted_bigram": jnp.zeros((B,), jnp.int32),          # accepted per src
    }


def generate(params, cfg: ModelConfig, spec: SpecConfig,
             prompt: jnp.ndarray, tables: Optional[NGramTables] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Generate up to max_new_tokens for every row of ``prompt`` (B, P).

    Returns (buf (B, L), buf_len (B,), stats).  jit-compatible end to end.
    """
    B, P = prompt.shape
    L = P + spec.max_new_tokens + spec.w + 2
    max_cache = L
    state = M.init_state(cfg, B, max_cache)
    buf = jnp.zeros((B, L), jnp.int32)
    buf = jax.lax.dynamic_update_slice(buf, prompt.astype(jnp.int32), (0, 0))

    logits_p, state = M.prefill(params, cfg, state, tokens=prompt)
    first = jnp.argmax(logits_p[:, -1], axis=-1).astype(jnp.int32)   # free token
    buf = buf.at[:, P].set(first)
    buf_len = jnp.full((B,), P + 1, jnp.int32)
    stats = _init_stats(spec, B)
    stats["tokens"] = stats["tokens"] + 1
    done = (first == spec.eos_id) if spec.eos_id >= 0 else jnp.zeros((B,), bool)

    attn_only = not M.has_recurrent(cfg)

    def cond(carry):
        _, buf_len_c, done_c, *_ = carry
        return (~done_c).any() & (buf_len_c - P < spec.max_new_tokens).any()

    def spec_body(carry):
        buf_c, len_c, done_c, state_c, st = carry
        last = jnp.take_along_axis(buf_c, (len_c - 1)[:, None], axis=1)[:, 0]
        drafts, valid, n_ctx = _draft(spec, tables, buf_c, len_c, last)
        rows = jnp.concatenate(
            [jnp.broadcast_to(last[:, None, None], (B, spec.k, 1)), drafts],
            axis=-1)                                                # (B,k,w+1)
        logits, tails = M.verify(params, cfg, state_c, rows)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        acc = accept(drafts, greedy)
        active = (~done_c) & (len_c - P < spec.max_new_tokens)
        budget = jnp.maximum(P + spec.max_new_tokens - len_c, 0)
        n_commit = jnp.where(active, jnp.minimum(acc.n_commit, budget), 0)
        # eos truncation: commit only up to (and including) the first eos
        if spec.eos_id >= 0:
            iseos = acc.tokens == spec.eos_id
            first_eos = jnp.argmax(iseos, axis=1)
            has_eos = iseos.any(axis=1) & (first_eos < n_commit)
            n_commit = jnp.where(has_eos, first_eos + 1, n_commit)
            done_c = done_c | (has_eos & active)
        # commit the model state
        if attn_only:
            state_n = M.commit_kv_tails(cfg, state_c, tails, acc.winner,
                                        n_commit)
        else:
            row_tok = jnp.take_along_axis(
                rows, acc.winner[:, None, None], axis=1)[:, 0]      # (B,w+1)
            _, state_n = M.decode(params, cfg, state_c, row_tok,
                                  n_commit=n_commit)
        # write accepted tokens into the buffer
        pos = jnp.arange(spec.w + 1)[None, :]
        slots = jnp.clip(len_c[:, None] + pos, 0, L - 1)
        gate = pos < n_commit[:, None]
        b_idx = jnp.broadcast_to(jnp.arange(B)[:, None], slots.shape)
        old = buf_c[b_idx, slots]
        buf_n = buf_c.at[b_idx, slots].set(
            jnp.where(gate, acc.tokens, old))
        len_n = len_c + n_commit
        done_n = done_c | (len_n - P >= spec.max_new_tokens)
        # ---- stats ----
        st = dict(st)
        st["calls"] = st["calls"] + active.astype(jnp.int32)
        st["tokens"] = st["tokens"] + n_commit
        st["accept_hist"] = st["accept_hist"].at[
            jnp.arange(B), jnp.clip(n_commit, 0, spec.w + 1)].add(
                active.astype(jnp.int32))
        n_win = jnp.take_along_axis(acc.n_acc, acc.winner[:, None], 1)[:, 0]
        st["rank_hist"] = st["rank_hist"].at[jnp.arange(B), acc.winner].add(
            (active & (n_win > 0)).astype(jnp.int32))
        st["alloc_ctx"] = st["alloc_ctx"].at[
            jnp.arange(B), jnp.clip(n_ctx, 0, spec.k)].add(
                active.astype(jnp.int32))
        from_ctx = acc.winner < n_ctx
        acc_drafted = jnp.maximum(n_commit - 1, 0)
        st["accepted_ctx"] = st["accepted_ctx"] + jnp.where(
            active & from_ctx, acc_drafted, 0)
        st["accepted_bigram"] = st["accepted_bigram"] + jnp.where(
            active & ~from_ctx, acc_drafted, 0)
        return (buf_n, len_n, done_n, state_n, st)

    def greedy_body(carry):
        buf_c, len_c, done_c, state_c, st = carry
        last = jnp.take_along_axis(buf_c, (len_c - 1)[:, None], axis=1)
        logits, state_n = M.decode(params, cfg, state_c, last)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        active = (~done_c) & (len_c - P < spec.max_new_tokens)
        slots = jnp.clip(len_c, 0, L - 1)
        buf_n = buf_c.at[jnp.arange(B), slots].set(
            jnp.where(active, nxt, buf_c[jnp.arange(B), slots]))
        len_n = len_c + active.astype(jnp.int32)
        done_n = done_c | (len_n - P >= spec.max_new_tokens)
        if spec.eos_id >= 0:
            done_n = done_n | (nxt == spec.eos_id)
        st = dict(st)
        st["calls"] = st["calls"] + active.astype(jnp.int32)
        st["tokens"] = st["tokens"] + active.astype(jnp.int32)
        return (buf_n, len_n, done_n, state_n, st)

    body = greedy_body if spec.strategy == "greedy" else spec_body
    carry = (buf, buf_len, done, state, stats)
    buf, buf_len, done, state, stats = jax.lax.while_loop(cond, body, carry)
    return buf, buf_len, stats


def greedy_reference(params, cfg: ModelConfig, prompt: jnp.ndarray,
                     max_new_tokens: int) -> jnp.ndarray:
    """Plain greedy decoding via full forward() only — the test oracle.

    Uses a FIXED-shape buffer (causality guarantees the garbage tail can't
    influence the position being read), so the whole loop compiles once.
    """
    B, P = prompt.shape
    L = P + max_new_tokens
    buf = jnp.zeros((B, L), jnp.int32)
    buf = jax.lax.dynamic_update_slice(buf, prompt.astype(jnp.int32), (0, 0))

    @jax.jit
    def step(buf, cur):
        logits, _ = M.forward(params, cfg, tokens=buf)
        nxt = jnp.take_along_axis(
            jnp.argmax(logits, axis=-1).astype(jnp.int32),
            (cur - 1)[None].repeat(B, 0)[:, None], axis=1)[:, 0]
        return buf.at[:, cur].set(nxt)

    for i in range(max_new_tokens):
        buf = step(buf, jnp.asarray(P + i))
    return buf
