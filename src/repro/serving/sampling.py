"""Sampling policies.  The paper's method verifies *greedy* continuations
(§Limitations: non-greedy speculative sampling is future work), so the spec
path is greedy-only; temperature sampling is provided for the plain path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(rng, logits: jnp.ndarray,
                       temperature: float = 1.0) -> jnp.ndarray:
    if temperature <= 0.0:
        return greedy(logits)
    return jax.random.categorical(rng, logits / temperature,
                                  axis=-1).astype(jnp.int32)
