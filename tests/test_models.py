"""Model substrate: prefill/decode/verify/commit consistency across families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import model as M
from repro.models.config import BlockSpec, ModelConfig

pytestmark = pytest.mark.slow  # model-level suite; excluded from -m 'not slow' fast lane

F32 = dict(param_dtype=jnp.float32, compute_dtype=jnp.float32, vocab_size=61)

FAMILIES = {
    "dense-gqa": ModelConfig(name="d", num_layers=2, d_model=64, num_heads=4,
                             num_kv_heads=2, d_ff=128, **F32),
    "mqa-geglu": ModelConfig(name="m", num_layers=2, d_model=64, num_heads=4,
                             num_kv_heads=1, d_ff=128, tie_embeddings=True,
                             scale_embed=True,
                             block_pattern=(BlockSpec("attn", "geglu"),),
                             **F32),
    "partial-rope-ln": ModelConfig(name="p", num_layers=2, d_model=64,
                                   num_heads=4, num_kv_heads=4, d_ff=128,
                                   norm="layernorm",
                                   partial_rotary_factor=0.5,
                                   block_pattern=(BlockSpec("attn", "relu2"),),
                                   **F32),
    "mrope": ModelConfig(name="q", num_layers=2, d_model=64, num_heads=4,
                         num_kv_heads=2, d_ff=128, rope="mrope",
                         mrope_sections=(4, 2, 2), **F32),
    "swa": ModelConfig(name="s", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=2, d_ff=128, sliding_window=8, **F32),
    "mamba": ModelConfig(name="mb", num_layers=2, d_model=64, num_heads=4,
                         num_kv_heads=4, d_ff=128, rope="none",
                         block_pattern=(BlockSpec("mamba", "swiglu"),), **F32),
    "hybrid-moe": ModelConfig(
        name="h", num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, num_experts=4, num_experts_per_tok=2,
        block_pattern=(BlockSpec("mamba", "swiglu"), BlockSpec("mamba", "moe"),
                       BlockSpec("attn", "swiglu"), BlockSpec("mamba", "moe")),
        **F32),
    "xlstm": ModelConfig(name="x", num_layers=2, d_model=64, num_heads=4,
                         num_kv_heads=4, d_ff=0, rope="none",
                         block_pattern=(BlockSpec("mlstm", "none"),
                                        BlockSpec("slstm", "none")), **F32),
    "deepseek": ModelConfig(name="ds", num_layers=3, d_model=64, num_heads=4,
                            num_kv_heads=4, d_ff=128, moe_d_ff=32,
                            num_experts=4, num_experts_per_tok=2,
                            num_shared_experts=1,
                            prefix_blocks=(BlockSpec("attn", "swiglu"),),
                            block_pattern=(BlockSpec("attn", "moe"),), **F32),
}


@pytest.mark.parametrize("family", list(FAMILIES))
def test_prefill_decode_verify_commit(family):
    cfg = FAMILIES[family].validate()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, T = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                              cfg.vocab_size)
    full, _ = M.forward(params, cfg, tokens=toks)
    assert bool(jnp.isfinite(full).all())

    state = M.init_state(cfg, B, 48)
    _, state = M.prefill(params, cfg, state, tokens=toks[:, :12])
    ld, state = M.decode(params, cfg, state, toks[:, 12:])
    np.testing.assert_allclose(np.asarray(ld), np.asarray(full[:, 12:]),
                               rtol=5e-4, atol=5e-4)

    k, w1 = 3, 4
    vt = jnp.broadcast_to(toks[:, 12:12 + w1][:, None], (B, k, w1))
    st2 = M.init_state(cfg, B, 48)
    _, st2 = M.prefill(params, cfg, st2, tokens=toks[:, :12])
    vl, tails = M.verify(params, cfg, st2, vt)
    np.testing.assert_allclose(np.asarray(vl[:, 0]),
                               np.asarray(full[:, 12:12 + w1]),
                               rtol=5e-4, atol=5e-4)

    # partial replay commit then continue
    ncommit = jnp.full((B,), 2, jnp.int32)
    _, st2 = M.decode(params, cfg, st2, vt[:, 0], n_commit=ncommit)
    assert int(st2["cur_len"][0]) == 14
    ld3, _ = M.decode(params, cfg, st2, toks[:, 14:15])
    np.testing.assert_allclose(np.asarray(ld3), np.asarray(full[:, 14:15]),
                               rtol=5e-4, atol=5e-4)


def test_commit_kv_tails_matches_replay(tiny_dense):
    cfg, params = tiny_dense
    B, T, k, w1 = 2, 12, 3, 4
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T + w1 + 1), 0,
                              cfg.vocab_size)
    vt = jnp.broadcast_to(toks[:, T:T + w1][:, None], (B, k, w1))
    sA = M.init_state(cfg, B, 48)
    _, sA = M.prefill(params, cfg, sA, tokens=toks[:, :T])
    _, tails = M.verify(params, cfg, sA, vt)
    n = jnp.full((B,), 3, jnp.int32)
    sA = M.commit_kv_tails(cfg, sA, tails, jnp.zeros((B,), jnp.int32), n)
    sB = M.init_state(cfg, B, 48)
    _, sB = M.prefill(params, cfg, sB, tokens=toks[:, :T])
    _, sB = M.decode(params, cfg, sB, vt[:, 0], n_commit=n)
    nxt = toks[:, T + 3:T + 4]
    lA, _ = M.decode(params, cfg, sA, nxt)
    lB, _ = M.decode(params, cfg, sB, nxt)
    np.testing.assert_allclose(np.asarray(lA), np.asarray(lB),
                               rtol=1e-5, atol=1e-5)


def test_encoder_only_forward():
    cfg = ModelConfig(name="enc", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=4, d_ff=128, causal=False,
                      encoder_only=True, embedding_inputs=True, rope="none",
                      block_pattern=(BlockSpec("attn", "gelu"),),
                      param_dtype=jnp.float32, compute_dtype=jnp.float32,
                      vocab_size=32).validate()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 64))
    logits, _ = M.forward(params, cfg, embeds=x)
    assert logits.shape == (2, 10, 32)
    assert bool(jnp.isfinite(logits).all())
    # bidirectional: flipping the sequence flips the outputs
    logits2, _ = M.forward(params, cfg, embeds=x[:, ::-1])
    np.testing.assert_allclose(np.asarray(logits2[:, ::-1]),
                               np.asarray(logits), rtol=2e-3, atol=2e-3)


def test_blockwise_attention_matches_dense():
    """Flash-style blockwise path == exact softmax attention."""
    import repro.models.attention as A
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="bw", num_layers=1, d_model=32, num_heads=4,
                      num_kv_heads=2, d_ff=64, vocab_size=11,
                      param_dtype=jnp.float32, compute_dtype=jnp.float32,
                      sliding_window=24).validate()
    B, T, H, hd, KV = 2, 32, 4, 8, 2
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, KV, hd))
    v = jax.random.normal(ks[2], (B, T, KV, hd))
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    kpos = jnp.where(pos < 30, pos, -1)     # padding mask exercised
    dense = A.masked_attention(q, k, v, pos, kpos, cfg, causal=True)
    bw = A._blockwise_attention(q, k, v, pos, kpos, cfg, causal=True,
                                block=8)
    np.testing.assert_allclose(np.asarray(bw), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)
