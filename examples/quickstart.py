"""Quickstart: the paper's method in ~40 lines of public API.

Trains a tiny byte-level LM, builds learning-free N-gram tables from its OWN
weights (P1: no draft training, P2: no external data), then generates with
batched speculation — output is bit-identical to greedy, in fewer calls.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.ngram_tables import NGramTables, build_bigram, build_unigram
from repro.core.spec_engine import SpecConfig, generate
from repro.data.pipeline import mixed_batches
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.train import AdamWConfig, init_train_state, make_train_step

# 1. a tiny model, trained for a few steps on synthetic code/math/chat
cfg = ModelConfig(name="quickstart", num_layers=2, d_model=128, num_heads=4,
                  num_kv_heads=2, d_ff=256, vocab_size=259,
                  param_dtype=jnp.float32, compute_dtype=jnp.float32)
ts = init_train_state(jax.random.PRNGKey(0), cfg)
step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, total_steps=100,
                                                warmup_steps=10)))
for batch in mixed_batches(8, 128, 100):
    ts, metrics = step(ts, jnp.asarray(batch))
print(f"trained: loss={float(metrics['loss']):.3f}")
params = ts["params"]

# 2. learning-free tables from the model itself (one-off sweep)
fwd = jax.jit(lambda t: M.forward(params, cfg, tokens=t)[0][:, -1])
bigram_topk, chain = build_bigram(fwd, cfg.vocab_size, k_max=10, w_max=10)
unigram = build_unigram(params["embed"]["embedding"],
                        params["embed"]["lm_head"], k_max=10)
tables = NGramTables(unigram, bigram_topk, chain)

# 3. batched speculation vs greedy — same output, fewer model calls
tok = ByteTokenizer()
prompt = jnp.asarray(tok.encode_batch(["def add_numbers(a, b):\n"], 24))
for strategy in ("greedy", "mixed"):
    spec = SpecConfig(k=10, w=10, strategy=strategy, max_new_tokens=64)
    buf, blen, stats = generate(params, cfg, spec, prompt, tables)
    text = tok.decode(buf[0, 24:int(blen[0])])
    tpc = float(stats["tokens"][0]) / max(int(stats["calls"][0]), 1)
    print(f"\n--- {strategy}: {int(stats['calls'][0])} calls, "
          f"{tpc:.2f} tokens/call ---")
    print(text)
