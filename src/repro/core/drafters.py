"""Draft strategies (paper §4): model-derived and context-derived N-grams.

Every drafter maps the current decode state to a fixed-shape batch of k
drafts of w tokens:  drafts (B, k, w) int32, valid (B, k) bool.  Invalid rows
are still verified (fixed shapes) but can never win more than the bonus
token, so correctness is unaffected — this is the fixed-shape TPU adaptation
of the paper's variable-length Python drafting.

The context N-gram uses a sort/hash reformulation of the paper's
``torch.unfold`` + ``torch.unique`` code (Appendix B.2), which is
jit-compatible: occurrence counts via sorted-hash range queries, recency
tie-break via a (count, position) lexicographic score, dedup by keeping the
latest occurrence of each continuation.  Hash collisions are possible but
*harmless*: a collision only merges the counts of two different
continuations; verification rejects any wrong token (output equals greedy
decoding bit-for-bit regardless).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .ngram_tables import NGramTables

_HASH_MULT = jnp.uint32(2654435761)   # Knuth multiplicative hash
_HASH_MIX = jnp.uint32(0x9E3779B9)


# ----------------------------------------------------------------------------
# model-derived drafters
# ----------------------------------------------------------------------------
def unigram_draft(tables: NGramTables, batch: int, k: int, w: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k unigram tokens, extended with bigram argmax chains (w > 1)."""
    first = tables.unigram_topk[:k]                       # (k,)
    drafts = _extend(tables, first[None].repeat(batch, 0), w)
    return drafts, jnp.ones((batch, k), bool)


def bigram_draft(tables: NGramTables, last_token: jnp.ndarray, k: int, w: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Extended model bigram (paper §4.1 'Extensions').

    last_token: (B,). Drafts row i = [topk_i(p(.|x)), argmax-chain...].
    """
    first = tables.bigram_topk[last_token][:, :k]         # (B, k)
    drafts = _extend(tables, first, w)
    return drafts, jnp.ones((first.shape[0], k), bool)


def _extend(tables: NGramTables, first: jnp.ndarray, w: int) -> jnp.ndarray:
    """first: (B, k) -> (B, k, w) via the precomputed argmax chain."""
    if w == 1:
        return first[..., None]
    tail = tables.bigram_chain[first][..., :w - 1]        # (B, k, w-1)
    return jnp.concatenate([first[..., None], tail], axis=-1)


# ----------------------------------------------------------------------------
# context-derived drafter
# ----------------------------------------------------------------------------
def _gram_matrix(buf: jnp.ndarray, width: int) -> jnp.ndarray:
    """buf: (L,) -> all windows (L - width + 1, width) (static shapes)."""
    L = buf.shape[0]
    return jnp.stack([buf[j:L - width + 1 + j] for j in range(width)], axis=-1)


def _hash_rows(rows: jnp.ndarray) -> jnp.ndarray:
    """Polynomial uint32 hash over the last axis."""
    h = jnp.zeros(rows.shape[:-1], jnp.uint32)
    for j in range(rows.shape[-1]):
        h = (h ^ (rows[..., j].astype(jnp.uint32) * _HASH_MULT)) * _HASH_MIX + 1
    return h


def _context_draft_row(buf: jnp.ndarray, cur_len: jnp.ndarray, q: int,
                       k: int, w: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single sequence. buf: (L,) int32; cur_len: () int32.

    Returns (drafts (k, w), valid (k,)).
    """
    L = buf.shape[0]
    width = q + w
    grams = _gram_matrix(buf, width)                      # (N, width), N=L-width+1
    N = grams.shape[0]
    query = jax.lax.dynamic_slice(buf, (jnp.maximum(cur_len - q, 0),), (q,))
    match = jnp.all(grams[:, :q] == query[None, :], axis=-1)
    idx = jnp.arange(N)
    match = match & (idx + width <= cur_len) & (cur_len >= q + 1)
    conts = grams[:, q:]                                  # (N, w)
    h = _hash_rows(conts)
    SENTINEL = jnp.uint32(0xFFFFFFFF)
    hm = jnp.where(match, h, SENTINEL)
    hs = jnp.sort(hm)
    lo = jnp.searchsorted(hs, hm, side="left")
    hi = jnp.searchsorted(hs, hm, side="right")
    counts = (hi - lo)                                    # occurrences
    # dedup: keep only the LATEST matching position of each continuation
    # (recency also breaks count ties, per the paper)
    later_same = jnp.zeros((N,), bool)
    # position j is dominated if any j' > j has same hash and matches
    # computed via a reverse cummax over (match ? idx : -1) per hash bucket —
    # equivalently: j is representative iff idx == max idx among its bucket.
    max_idx_sorted = jnp.where(match, idx, -1)
    # scatter-max over hash buckets using sort by hash
    order = jnp.argsort(hm)
    h_sorted = hm[order]
    i_sorted = max_idx_sorted[order]
    # running max within equal-hash runs (left to right)
    def scan_fn(carry, x):
        prev_h, prev_m = carry
        hh, ii = x
        m = jnp.where(hh == prev_h, jnp.maximum(prev_m, ii), ii)
        return (hh, m), m
    _, run_max = jax.lax.scan(scan_fn, (SENTINEL ^ 1, jnp.int32(-1)),
                              (h_sorted, i_sorted), reverse=False)
    # propagate run max backwards (max of run is at run end): reverse scan
    def scan_back(carry, x):
        prev_h, prev_m = carry
        hh, mm = x
        m = jnp.where(hh == prev_h, jnp.maximum(prev_m, mm), mm)
        return (hh, m), m
    _, bucket_max_sorted = jax.lax.scan(scan_back, (SENTINEL ^ 1, jnp.int32(-1)),
                                        (h_sorted, run_max), reverse=True)
    bucket_max = jnp.zeros((N,), jnp.int32).at[order].set(bucket_max_sorted)
    is_rep = match & (idx == bucket_max)
    # top-k by (count, recency), overflow-free: lexsort ascending by
    # (idx, count) with invalid rows pushed to the front, take the last k.
    cnt_key = jnp.where(is_rep, counts.astype(jnp.int32), -1)
    order2 = jnp.lexsort((idx, cnt_key))                  # ascending
    top_idx = order2[-k:][::-1]
    drafts = conts[top_idx]                               # (k, w)
    valid = cnt_key[top_idx] >= 0
    return drafts.astype(jnp.int32), valid


def context_ngram_draft(buf: jnp.ndarray, cur_len: jnp.ndarray, q: int,
                        k: int, w: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """buf: (B, L); cur_len: (B,). Returns (drafts (B,k,w), valid (B,k))."""
    return jax.vmap(lambda b, c: _context_draft_row(b, c, q, k, w))(buf,
                                                                    cur_len)


# ----------------------------------------------------------------------------
# mixed strategy (paper §4.3)
# ----------------------------------------------------------------------------
def mixed_draft(tables: NGramTables, buf: jnp.ndarray, cur_len: jnp.ndarray,
                last_token: jnp.ndarray, q: int, k: int, w: int
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Context N-gram matches first, extended model bigram fills the rest.

    Returns (drafts (B,k,w), valid (B,k), n_context (B,) — allocation stat).
    """
    ctx_d, ctx_v = context_ngram_draft(buf, cur_len, q, k, w)
    big_d, _ = bigram_draft(tables, last_token, k, w)
    B = buf.shape[0]
    # compact the valid context drafts to the front, bigram after
    order = jnp.argsort(~ctx_v, axis=1, stable=True)       # valid first
    ctx_sorted = jnp.take_along_axis(ctx_d, order[..., None], axis=1)
    n_ctx = ctx_v.sum(axis=1)                              # (B,)
    row = jnp.arange(k)[None, :]
    use_ctx = row < n_ctx[:, None]
    big_idx = jnp.clip(row - n_ctx[:, None], 0, k - 1)
    big_fill = jnp.take_along_axis(big_d, big_idx[..., None], axis=1)
    drafts = jnp.where(use_ctx[..., None], ctx_sorted, big_fill)
    valid = jnp.ones((B, k), bool)
    return drafts, valid, n_ctx.astype(jnp.int32)
