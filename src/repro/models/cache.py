"""Decode-state management: KV caches (linear, sliding-window ring, paged),
SSM and xLSTM recurrent states, and the speculative *commit* semantics.

Paper mapping (Appendix D): the paper keeps a batched (k-row) static KV cache,
initialised from a k=1 cache by broadcasting, and after each verification
overwrites all rows with the winning row's accepted entries.  Our TPU-native
default is the *bifurcated* variant instead: ONE shared cache of the context,
per-row KV only for the in-flight (w+1)-token speculative tail; commit writes
the winner's accepted tail into the shared cache.  This removes the k× HBM
traffic (and k× memory) of the paper's layout — see DESIGN.md §3 and
EXPERIMENTS.md §Perf where both layouts are measured.

State layout (everything stacked over the R periods of the layer pattern so
the transformer can ``lax.scan`` over it):

  state = {
    "cur_len": (B,) int32   — #positions committed per sequence,
    "groups": {gid: {...}}  — gid = "pre{i}" or "p{j}"; every leaf has
                               leading dim R (R=1 for prefix groups).
  }

Paged layout (DESIGN.md §8): instead of a per-slot linear buffer
(R, B, S, KV, hd), attention groups hold ONE shared page pool
(R, num_pages, page_size, KV, hd) and the state grows four extra leaves:

    "page_table": (B, pages_per_slot) int32  — physical page per logical
                                               page, -1 = unallocated,
    "n_pages":    (B,) int32                 — allocated pages per slot,
    "free_list":  (num_pages,) int32         — free-page stack,
    "free_top":   () int32                   — #free pages (stack pointer).

The page table is shared by every layer (physical page p of every group's
pool belongs to the same slot), page_size matches the Pallas verify
kernel's ``block_s`` cache-streaming grid, and alloc/free/grow are pure
jnp scatter/gather so they run inside the jitted admit/release/spec-step
path.  Recurrent leaves stay per-slot (they are O(1) in sequence length).
Presence of "page_table" is what flags a state as paged (`is_paged`).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ATTN, MAMBA, MLSTM, SLSTM, BlockSpec, ModelConfig


def cache_buffer_len(cfg: ModelConfig, max_len: int) -> int:
    """Physical KV buffer length: window-sized ring when sliding-window."""
    if cfg.sliding_window is not None and cfg.sliding_window < max_len:
        return cfg.sliding_window
    return max_len


def group_ids(cfg: ModelConfig):
    """Yield (gid, BlockSpec, R) for prefix and body pattern positions."""
    out = []
    for i, b in enumerate(cfg.prefix_blocks):
        out.append((f"pre{i}", b, 1))
    for j, b in enumerate(cfg.block_pattern):
        out.append((f"p{j}", b, cfg.num_periods))
    return out


def _init_group(cfg: ModelConfig, spec: BlockSpec, R: int, batch: int,
                S: int) -> Dict:
    """Empty decode-state group for one layer position (linear ATTN layout)."""
    hd = cfg.resolved_head_dim
    if spec.mixer == ATTN:
        shape = (R, batch, S, cfg.num_kv_heads, hd)
        return {"k": jnp.zeros(shape, cfg.compute_dtype),
                "v": jnp.zeros(shape, cfg.compute_dtype)}
    elif spec.mixer == MAMBA:
        return {
            "conv": jnp.zeros((R, batch, cfg.mamba_d_conv - 1,
                               cfg.mamba_d_inner), cfg.compute_dtype),
            "ssm": jnp.zeros((R, batch, cfg.mamba_d_inner,
                              cfg.mamba_d_state), jnp.float32)}
    elif spec.mixer == MLSTM:
        di = int(cfg.d_model * cfg.xlstm_mlstm_proj_factor)
        nh = cfg.num_heads
        dh = di // nh
        return {
            "C": jnp.zeros((R, batch, nh, dh, dh), jnp.float32),
            "n": jnp.zeros((R, batch, nh, dh), jnp.float32),
            "m": jnp.full((R, batch, nh), -1e9, jnp.float32),
            "conv": jnp.zeros((R, batch, cfg.xlstm_conv_kernel - 1, di),
                              cfg.compute_dtype)}
    elif spec.mixer == SLSTM:
        nh = cfg.num_heads
        dh = cfg.d_model // nh
        # distinct buffers per leaf: sharing one zeros array here makes
        # donation of the enclosing state illegal ("same buffer donated
        # twice" in the jitted admit/spec-step path)
        z = lambda: jnp.zeros((R, batch, nh, dh), jnp.float32)
        return {"c": z(), "n": z(), "h": z(),
                "m": jnp.full((R, batch, nh, dh), -1e9, jnp.float32)}
    raise ValueError(spec.mixer)


def init_state(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """Allocate an empty decode state for ``batch`` sequences."""
    S = cache_buffer_len(cfg, max_len)
    groups = {gid: _init_group(cfg, spec, R, batch, S)
              for gid, spec, R in group_ids(cfg)}
    return {"cur_len": jnp.zeros((batch,), jnp.int32), "groups": groups}


# ----------------------------------------------------------------------------
# slot management (continuous batching)
# ----------------------------------------------------------------------------
def insert_slot(state: Dict, row_state: Dict, slot) -> Dict:
    """Overwrite batch slot ``slot`` of ``state`` with a batch-1 state.

    ``row_state`` comes from prefilling one request in isolation (batch 1,
    same ``max_len``); writing it over the slot replaces *every* leaf of the
    previous occupant — KV rows, recurrent states and cur_len — so request
    N+1 in a reused slot cannot observe request N's cache.  ``slot`` may be
    a traced scalar (jit-compatible admission).
    """
    def ins(leaf, row):
        if leaf.shape[2:] != row.shape[2:] or row.shape[1] != 1:
            raise ValueError(f"slot insert shape mismatch: {leaf.shape} "
                             f"vs {row.shape}")
        return leaf.at[:, slot].set(row[:, 0])

    groups = {gid: jax.tree_util.tree_map(ins, g, row_state["groups"][gid])
              for gid, g in state["groups"].items()}
    return {"cur_len": state["cur_len"].at[slot].set(row_state["cur_len"][0]),
            "groups": groups}


def zero_slot_stats(stats: Dict, slot) -> Dict:
    """Zero batch slot ``slot``'s row in every per-slot stats array.

    Works for any trailing shape — scalar counters (B,), histograms (B, n)
    and the adaptive controller's per-arm state (B, A) alike — so slot
    admission/release resets the bandit with the same sweep that resets the
    call/token counters: a reused slot can never inherit the previous
    request's arm rewards (DESIGN.md §9 donation/reset rules).  ``slot`` may
    be traced (used inside the jitted admit/release paths).
    """
    return {k: v.at[slot].set(jnp.zeros((), v.dtype))
            for k, v in stats.items()}


def reset_slot(cfg: ModelConfig, state: Dict, slot) -> Dict:
    """Reset batch slot ``slot`` to the freshly-initialised empty state.

    Passing the existing physical buffer length S back through init_state is
    shape-stable: cache_buffer_len(cfg, S) == S whether S came from a linear
    cache or a window-sized ring, and recurrent leaves ignore max_len.
    Paged states free the slot's pages instead of zeroing KV (a freed page
    is never read: phys_slots maps unallocated positions out of bounds).
    """
    if is_paged(state):
        state = free_slot_pages(state, slot)
        empty = init_state(cfg, 1, 1)
        groups = dict(state["groups"])
        for gid, g in state["groups"].items():
            if "k" in g:
                continue                      # pool pages already reclaimed
            groups[gid] = jax.tree_util.tree_map(
                lambda leaf, row: leaf.at[:, slot].set(row[:, 0]),
                g, empty["groups"][gid])
        return {**state, "groups": groups,
                "cur_len": state["cur_len"].at[slot].set(0)}
    S = 1
    for gid, spec, _ in group_ids(cfg):
        if spec.mixer == ATTN:
            S = state["groups"][gid]["k"].shape[2]
            break
    return insert_slot(state, init_state(cfg, 1, S), slot)


# ----------------------------------------------------------------------------
# paged KV cache (DESIGN.md §8)
# ----------------------------------------------------------------------------
def default_page_size(cfg: ModelConfig) -> int:
    """Pages match the Pallas verify kernel's cache-streaming block: one page
    == one ``block_s`` VMEM block, so the paged kernel's grid steps map 1:1
    onto pages and the pool layout needs no per-call repacking."""
    if cfg.kernel_block_s:
        return cfg.kernel_block_s
    from ..kernels.spec_attention import DEFAULT_BLOCK_S
    return DEFAULT_BLOCK_S


def paged_supported(cfg: ModelConfig) -> bool:
    """Paged layout implements linear-cache semantics only: sliding-window
    ring caches keep the per-slot ring buffer, and at least one attention
    group must exist for paging to mean anything."""
    return (cfg.sliding_window is None
            and any(spec.mixer == ATTN for _, spec, _ in group_ids(cfg)))


def is_paged(state: Dict) -> bool:
    return "page_table" in state


def paged_dims(state: Dict) -> Tuple[int, int, int]:
    """(num_pages, page_size, pages_per_slot) of a paged state."""
    pool = next(g["k"] for g in state["groups"].values() if "k" in g)
    return pool.shape[1], pool.shape[2], state["page_table"].shape[1]


def init_paged_state(cfg: ModelConfig, batch: int, num_pages: int,
                     page_size: int, pages_per_slot: int) -> Dict:
    """Allocate an empty PAGED decode state: attention groups hold a shared
    (R, num_pages, page_size, KV, hd) pool, all pages start on the free
    stack, and every slot's page table is empty."""
    assert paged_supported(cfg), (
        f"{cfg.name}: paged KV requires a linear-cache attention arch "
        f"(sliding_window=None, >=1 attn layer)")
    hd = cfg.resolved_head_dim
    groups = {}
    for gid, spec, R in group_ids(cfg):
        if spec.mixer == ATTN:
            shape = (R, num_pages, page_size, cfg.num_kv_heads, hd)
            groups[gid] = {"k": jnp.zeros(shape, cfg.compute_dtype),
                           "v": jnp.zeros(shape, cfg.compute_dtype)}
        else:
            groups[gid] = _init_group(cfg, spec, R, batch, 0)
    return {"cur_len": jnp.zeros((batch,), jnp.int32),
            "groups": groups,
            "page_table": jnp.full((batch, pages_per_slot), -1, jnp.int32),
            "n_pages": jnp.zeros((batch,), jnp.int32),
            "free_list": jnp.arange(num_pages, dtype=jnp.int32),
            "free_top": jnp.asarray(num_pages, jnp.int32)}


def pages_for_len(length, page_size: int):
    """Pages needed to hold ``length`` positions (works traced or concrete)."""
    return (length + page_size - 1) // page_size


def phys_slots(page_table: jnp.ndarray, pos: jnp.ndarray, page_size: int,
               num_pages: int) -> jnp.ndarray:
    """Physical pool slot for each logical position. pos: (B, T) int32.

    Positions without an allocated page map to the out-of-bounds sentinel
    ``num_pages * page_size`` so scatter writes with ``mode='drop'`` discard
    them (never clamp: a clamped index would silently write into another
    slot's page).
    """
    B, PPS = page_table.shape
    pg = pos // page_size
    pid = jnp.take_along_axis(page_table, jnp.clip(pg, 0, PPS - 1), axis=1)
    ok = (pos >= 0) & (pg < PPS) & (pid >= 0)
    return jnp.where(ok, pid * page_size + pos % page_size,
                     num_pages * page_size).astype(jnp.int32)


def paged_kv_write(k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                   k_new: jnp.ndarray, v_new: jnp.ndarray,
                   phys: jnp.ndarray,
                   gate: Optional[jnp.ndarray] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter new KV into the shared pool.  pools: (N, ps, KV, hd);
    k_new/v_new: (B, T, KV, hd); phys: (B, T) physical slots (flattened pool
    indexing, out-of-bounds = skip); gate: (B, T) bool — write where True.

    Distinct slots own distinct pages, so flattened scatter indices never
    collide across batch rows; gated-off / unallocated writes fall on the
    out-of-bounds sentinel and are dropped.
    """
    N, ps = k_pool.shape[:2]
    tail = k_pool.shape[2:]
    if gate is not None:
        phys = jnp.where(gate, phys, N * ps)
    idx = phys.reshape(-1)
    kf = k_pool.reshape((N * ps,) + tail)
    vf = v_pool.reshape((N * ps,) + tail)
    kf = kf.at[idx].set(k_new.reshape((-1,) + tail).astype(kf.dtype),
                        mode="drop")
    vf = vf.at[idx].set(v_new.reshape((-1,) + tail).astype(vf.dtype),
                        mode="drop")
    return kf.reshape(k_pool.shape), vf.reshape(v_pool.shape)


def gather_pages(k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                 page_table: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Materialise the per-slot linear view (B, pages_per_slot*ps, KV, hd)
    of the pool — the XLA fallback's read path.  Unallocated pages clamp to
    physical page 0; every position they cover is >= cur_len, so the
    verify-attention mask already hides the garbage.
    """
    N = k_pool.shape[0]
    B, PPS = page_table.shape
    pid = jnp.clip(page_table, 0, N - 1)               # (B, PPS)
    ps = k_pool.shape[1]
    tail = k_pool.shape[2:]
    k_lin = k_pool[pid].reshape((B, PPS * ps) + tail)
    v_lin = v_pool[pid].reshape((B, PPS * ps) + tail)
    return k_lin, v_lin


def alloc_slot_pages(state: Dict, slot, n_new) -> Dict:
    """Pop ``n_new`` pages off the free stack into ``slot``'s page table
    (appended after its currently-allocated pages).  jit-compatible: ``slot``
    and ``n_new`` may be traced.  The caller guarantees n_new <= free_top
    (the serving engine's page-reservation admission does; see engine.py).
    """
    pt, npg = state["page_table"], state["n_pages"]
    fl, ft = state["free_list"], state["free_top"]
    PPS, N = pt.shape[1], fl.shape[0]
    cur = npg[slot]
    idx = jnp.arange(PPS)
    j = idx - cur                                   # j-th newly-added page
    take = (j >= 0) & (j < n_new)
    src = ft - 1 - j
    grant = take & (src >= 0) & (src < N)
    row = jnp.where(grant, fl[jnp.clip(src, 0, N - 1)], pt[slot])
    return {**state,
            "page_table": pt.at[slot].set(row),
            "n_pages": npg.at[slot].set(cur + grant.sum().astype(jnp.int32)),
            "free_top": jnp.maximum(ft - n_new, 0).astype(jnp.int32)}


def free_slot_pages(state: Dict, slot) -> Dict:
    """Push every page of ``slot`` back onto the free stack and clear its
    table.  Idempotent: a slot with n_pages == 0 is a no-op, so release
    followed by a defensive free at admission cannot double-free."""
    pt, npg = state["page_table"], state["n_pages"]
    fl, ft = state["free_list"], state["free_top"]
    PPS, N = pt.shape[1], fl.shape[0]
    n = npg[slot]
    idx = jnp.arange(PPS)
    dst = jnp.where(idx < n, ft + idx, N)           # OOB sentinel -> dropped
    fl = fl.at[dst].set(pt[slot], mode="drop")
    return {**state,
            "free_list": fl,
            "free_top": (ft + n).astype(jnp.int32),
            "page_table": pt.at[slot].set(jnp.full((PPS,), -1, jnp.int32)),
            "n_pages": npg.at[slot].set(0)}


def grow_pages(state: Dict, required_len: jnp.ndarray,
               active: jnp.ndarray) -> Dict:
    """Batched on-the-fly growth: ensure every ``active`` slot has pages
    covering ``required_len`` positions (spec_step calls this each iteration
    with cur_len + w + 1, so commits never outrun the table).

    Pops sum(need) pages in one vectorised step; on exhaustion a slot's
    missing pages stay -1 (its writes drop, reads mask — row-local
    corruption at worst, never another slot's pages).  The engine's
    reservation admission keeps exhaustion unreachable in serving.
    """
    pt, npg = state["page_table"], state["n_pages"]
    fl, ft = state["free_list"], state["free_top"]
    B, PPS = pt.shape
    N = fl.shape[0]
    ps = paged_dims(state)[1]
    need = jnp.maximum(pages_for_len(required_len, ps) - npg, 0)
    need = jnp.where(active, need, 0).astype(jnp.int32)
    offs = jnp.cumsum(need) - need                  # exclusive prefix (B,)
    idx = jnp.arange(PPS)[None, :]
    j = idx - npg[:, None]                          # j-th new page per row
    take = (j >= 0) & (j < need[:, None])
    src = ft - 1 - (offs[:, None] + j)
    grant = take & (src >= 0)
    new_pt = jnp.where(grant, fl[jnp.clip(src, 0, N - 1)], pt)
    return {**state,
            "page_table": new_pt,
            "n_pages": npg + grant.sum(axis=1).astype(jnp.int32),
            "free_top": jnp.maximum(ft - need.sum(), 0).astype(jnp.int32)}


def insert_slot_paged(state: Dict, row_state: Dict, slot,
                      row_len: int) -> Dict:
    """Paged counterpart of insert_slot: scatter a prefilled batch-1 LINEAR
    row state (buffer length ``row_len``, cur_len == row_len) into the pool
    pages already allocated to ``slot``; recurrent leaves copy as usual.

    The caller allocates ceil(row_len / page_size) pages first
    (alloc_slot_pages) — spec_engine.admit_slot does both inside one jit.
    """
    N, ps, _ = paged_dims(state)
    pos = jnp.arange(row_len, dtype=jnp.int32)[None, :]          # (1, row_len)
    phys = phys_slots(state["page_table"][slot][None], pos, ps, N)
    groups = dict(state["groups"])
    for gid, g in state["groups"].items():
        row_g = row_state["groups"][gid]
        if "k" in g:                                 # attention group -> pool
            # row KV is (R, 1, row_len, KV, hd); vmap over R hands
            # paged_kv_write the (1, row_len, KV, hd) batch it expects
            kc, vc = jax.vmap(
                lambda kp, vp, kr, vr: paged_kv_write(kp, vp, kr, vr, phys)
            )(g["k"], g["v"], row_g["k"], row_g["v"])
            groups[gid] = {"k": kc, "v": vc}
        else:
            groups[gid] = jax.tree_util.tree_map(
                lambda leaf, row: leaf.at[:, slot].set(row[:, 0]), g, row_g)
    return {**state, "groups": groups,
            "cur_len": state["cur_len"].at[slot].set(row_state["cur_len"][0])}


def check_page_invariants(state: Dict) -> Dict:
    """Host-side free-list/page-table audit (tests + debugging).

    Asserts: allocated pages are unique, disjoint from the free stack, and
    together with it cover exactly {0..num_pages-1}; every page table row is
    n_pages valid entries followed by -1s.  Returns summary counts.
    """
    import numpy as np
    pt = np.asarray(state["page_table"])
    npg = np.asarray(state["n_pages"])
    fl = np.asarray(state["free_list"])
    ft = int(np.asarray(state["free_top"]))
    N = fl.shape[0]
    allocated = []
    for b in range(pt.shape[0]):
        row = pt[b]
        n = int(npg[b])
        assert (row[:n] >= 0).all(), (b, row, n)
        assert (row[n:] == -1).all(), (b, row, n)
        allocated.extend(row[:n].tolist())
    free = fl[:ft].tolist()
    assert len(set(allocated)) == len(allocated), "page double-mapped"
    assert not (set(allocated) & set(free)), "allocated page on free stack"
    assert set(allocated) | set(free) == set(range(N)), (
        f"page leak: {sorted(set(range(N)) - set(allocated) - set(free))}")
    return {"num_pages": N, "free": ft, "allocated": len(allocated)}


# ----------------------------------------------------------------------------
# position bookkeeping
# ----------------------------------------------------------------------------
def key_positions(cfg: ModelConfig, S: int, cur_len: jnp.ndarray) -> jnp.ndarray:
    """Absolute position stored in each cache slot; -1 where empty.

    cur_len: (B,). Linear cache: slot s holds position s if s < cur_len.
    Ring cache (window W=S): slot s holds the largest p < cur_len with
    p % W == s, valid if p >= 0 and p >= cur_len - W.
    """
    B = cur_len.shape[0]
    slots = jnp.arange(S)[None, :]                      # (1, S)
    cl = cur_len[:, None]                               # (B, 1)
    if cfg.sliding_window is not None and cfg.sliding_window <= S:
        # ring semantics
        p = cl - 1 - jnp.mod(cl - 1 - slots, S)
        valid = (p >= 0) & (p >= cl - S) & (cl > 0)
        return jnp.where(valid, p, -1).astype(jnp.int32)
    pos = jnp.broadcast_to(slots, (B, S))
    return jnp.where(pos < cl, pos, -1).astype(jnp.int32)


def write_slots(cfg: ModelConfig, S: int, cur_len: jnp.ndarray,
                T_new: int) -> jnp.ndarray:
    """Cache slots for the next T_new positions. (B, T_new) int32."""
    pos = cur_len[:, None] + jnp.arange(T_new)[None, :]
    if cfg.sliding_window is not None and cfg.sliding_window <= S:
        return jnp.mod(pos, S).astype(jnp.int32)
    return pos.astype(jnp.int32)


def kv_write(k_cache: jnp.ndarray, v_cache: jnp.ndarray,
             k_new: jnp.ndarray, v_new: jnp.ndarray,
             slots: jnp.ndarray,
             gate: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray,
                                                          jnp.ndarray]:
    """Write new KV into slots. caches: (B,S,KV,hd); new: (B,T,KV,hd);
    slots: (B,T). ``gate``: (B,T) bool — write only where True (spec commit).

    T == 1 (the production serve step) uses a one-hot masked select instead
    of a scatter: elementwise ops partition cleanly when the cache sequence
    dim is sharded over the `model` axis, whereas a scatter with dynamic
    per-row indices makes GSPMD all-gather the whole cache every layer
    (EXPERIMENTS §Perf it-6).  Multi-token writes (speculative verify
    commits) keep the scatter path.
    """
    B, T = slots.shape
    S = k_cache.shape[1]
    if T == 1:
        hit = (jnp.arange(S)[None, :] == slots)            # (B, S)
        if gate is not None:
            hit = hit & gate
        m = hit[..., None, None]
        k_cache = jnp.where(m, k_new.astype(k_cache.dtype), k_cache)
        v_cache = jnp.where(m, v_new.astype(v_cache.dtype), v_cache)
        return k_cache, v_cache
    b_idx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, T))
    if gate is not None:
        old_k = k_cache[b_idx, slots]
        old_v = v_cache[b_idx, slots]
        k_new = jnp.where(gate[..., None, None], k_new.astype(k_cache.dtype),
                          old_k)
        v_new = jnp.where(gate[..., None, None], v_new.astype(v_cache.dtype),
                          old_v)
    k_cache = k_cache.at[b_idx, slots].set(k_new.astype(k_cache.dtype))
    v_cache = v_cache.at[b_idx, slots].set(v_new.astype(v_cache.dtype))
    return k_cache, v_cache


def prefill_write(cfg: ModelConfig, k_cache, v_cache, k_new, v_new,
                  seq_mask: Optional[jnp.ndarray] = None):
    """Write a full prefill block (positions 0..T-1) into an empty cache.

    With a ring cache only the last S positions land (earlier ones are
    overwritten by the mod-S scatter, in order, which is exactly ring
    semantics).
    """
    B, T = k_new.shape[:2]
    S = k_cache.shape[1]
    if T > S:
        # ring cache shorter than the prompt: only the last S positions land
        # (slice explicitly — a mod-S scatter with duplicate slots would have
        # undefined winner order).
        k_new, v_new = k_new[:, -S:], v_new[:, -S:]
        if seq_mask is not None:
            seq_mask = seq_mask[:, -S:]
        off = jnp.full((B,), T - S, jnp.int32)
        slots = write_slots(cfg, S, off, S)
        return kv_write(k_cache, v_cache, k_new, v_new, slots, gate=seq_mask)
    cur0 = jnp.zeros((B,), jnp.int32)
    slots = write_slots(cfg, S, cur0, T)
    return kv_write(k_cache, v_cache, k_new, v_new, slots, gate=seq_mask)


# ----------------------------------------------------------------------------
# recurrent-state select helpers (used by gated replay commit)
# ----------------------------------------------------------------------------
def select_step_state(states_per_step, old_state, n_commit: jnp.ndarray):
    """states_per_step: pytree with leading (B, T, ...) per-step states;
    old_state: matching (B, ...). Returns state after n_commit steps
    (old state where n_commit == 0)."""
    def sel(per_step, old):
        B, T = per_step.shape[:2]
        idx = jnp.clip(n_commit - 1, 0, T - 1)
        picked = jnp.take_along_axis(
            per_step, idx.reshape((B,) + (1,) * (per_step.ndim - 1)), axis=1
        )[:, 0]
        return jnp.where(
            (n_commit > 0).reshape((B,) + (1,) * (old.ndim - 1)), picked, old)
    return jax.tree_util.tree_map(sel, states_per_step, old_state)
