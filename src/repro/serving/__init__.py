from . import engine, sampling, scheduler  # noqa: F401
from .engine import ServingEngine  # noqa: F401
from .scheduler import Request, Scheduler, SlotMap  # noqa: F401
