"""``python -m repro.analysis`` — the repro-lint CLI.

Exit status: 0 when every finding is waived or baselined, 1 otherwise
(``--strict`` is the CI spelling of the same gate and additionally fails
when the baseline file itself has gone stale — entries that no longer
match any finding must be deleted, keeping the baseline a ratchet).
"""
from __future__ import annotations

import argparse
import json
import sys

from . import DEFAULT_BASELINE, RULES, Baseline, run_all


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: static checks of the engine's "
                    "lossless-speculation contracts (DESIGN.md §13)")
    ap.add_argument("--strict", action="store_true",
                    help="fail on new findings AND on stale baseline "
                         "entries (the CI gate)")
    ap.add_argument("--level", type=int, choices=(1, 2), default=None,
                    help="run only jaxpr (1) or AST (2) rules; default both")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="accepted-findings file (default: "
                         "src/repro/analysis/baseline.json)")
    ap.add_argument("--syncmap", metavar="PATH",
                    help="write the full host-sync inventory (waived "
                         "included) as JSON, e.g. BENCH_syncmap.json")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON instead of text")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in RULES.items():
            print(f"{rule:20s} {desc}")
        return 0

    findings, inventory = run_all(level=args.level)
    baseline = Baseline.load(args.baseline)
    new, accepted = baseline.split(findings)
    stale = [e for e in baseline.entries
             if (e["rule"], e["file"], e.get("context", ""))
             not in {f.key for f in findings}]

    if args.syncmap:
        with open(args.syncmap, "w") as f:
            json.dump({"inventory": inventory,
                       "total": len(inventory),
                       "waived": sum(1 for e in inventory if e["waived"])},
                      f, indent=2)
            f.write("\n")
        print(f"syncmap: {len(inventory)} sync sites -> {args.syncmap}")

    if args.json:
        print(json.dumps({"new": [f.to_dict() for f in new],
                          "accepted": [f.to_dict() for f in accepted],
                          "stale_baseline": stale}, indent=2))
    else:
        for f in new:
            print(f.format())
        n_waived = sum(1 for f in accepted if f.waived)
        print(f"repro-lint: {len(new)} new finding(s), "
              f"{len(accepted)} accepted ({n_waived} waived, "
              f"{len(accepted) - n_waived} baselined), "
              f"{len(stale)} stale baseline entr(y/ies)")
        if stale and args.strict:
            for e in stale:
                print(f"  stale baseline entry: {e['rule']} @ {e['file']} "
                      f"({e.get('context', '')!r}) — delete it")

    if new:
        return 1
    if args.strict and stale:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
