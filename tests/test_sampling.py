"""Lossless speculative sampling (DESIGN.md §12).

The contract under test, both directions:

* temperature == 0 rows are BIT-EXACT greedy — a sampling-enabled step
  (``SpecConfig.sampling=True``) reproduces ``greedy_reference`` token for
  token across every drafting strategy, both kernel backends, linear and
  paged KV layouts, adaptive arms, and tree mode.
* temperature > 0 rows draw from the TARGET distribution — the spec path's
  rejection-verified trajectories match the plain autoregressive sampler
  ``sampling_reference`` in distribution (TV / chi-square on large seeded
  batches, with a mismatched-temperature control establishing the test has
  power), while committing > 1 token per verify call often enough to matter.

Also pinned here (the satellite bugfixes):

* ``serving.sampling.temperature_sample`` raises on negative temperature
  and upcasts half-precision logits before the temperature division.
* eos/budget retirement around the bonus token: a row never overshoots its
  budget and stops at the first eos even when that token arrives as the
  rejection bonus on the final call.
* ``stats["accept_hist"]`` invariant: bin 0 structurally zero and
  ``hist.sum() == calls`` on every path, including plain-greedy bodies.

One compiled step serves mixed greedy/sampled continuous batches (compile
count spy), a pinned-greedy engine rejects sampled requests at admission,
and seeded runs replay bit-identically.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spec_engine
from repro.core.ngram_tables import NGramTables, build_bigram, build_unigram
from repro.core.spec_engine import (PagedConfig, SpecConfig, generate,
                                    greedy_reference, init_decode_state,
                                    sampling_reference)
from repro.core.verify import (per_row_keys, residual_pmf,
                               sample_predictions, sample_token,
                               shape_logits)
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving import ServingEngine
from repro.serving.sampling import temperature_sample

F32 = dict(param_dtype=jnp.float32, compute_dtype=jnp.float32)

STRATEGIES = ["mixed", "bigram", "unigram", "context", "greedy"]


@pytest.fixture(scope="module")
def model():
    """Kernel-eligible tiny arch (small block so pallas interpret is fast)."""
    cfg = ModelConfig(name="sampling", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=61,
                      backend="xla", kernel_block_s=16, **F32).validate()
    return cfg, M.init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def tables(model):
    cfg, params = model
    fwd = jax.jit(lambda t: M.forward(params, cfg, tokens=t)[0][:, -1])
    topk, chain = build_bigram(fwd, cfg.vocab_size, k_max=8, w_max=8,
                               batch=cfg.vocab_size)
    uni = build_unigram(params["embed"]["embedding"],
                        params["embed"]["lm_head"], k_max=8)
    return NGramTables(uni, topk, chain)


def _prompt(cfg, B=2, P=10, seed=5):
    return jax.random.randint(jax.random.PRNGKey(seed), (B, P), 0,
                              cfg.vocab_size)


# ---------------------------------------------------------------------------
# shape_logits: the one shared shaping function
# ---------------------------------------------------------------------------
def test_shape_logits_upcasts_before_scaling():
    # f16 logits / tiny temperature overflows half precision; the shaped
    # result must be finite f32 (and preserve the ordering)
    logits = jnp.asarray([[400.0, 300.0, -50.0]], jnp.float16)
    shaped = shape_logits(logits, 1e-3)
    assert shaped.dtype == jnp.float32
    assert bool(jnp.isfinite(shaped).all())
    assert int(jnp.argmax(shaped, axis=-1)[0]) == 0


def test_shape_logits_top_p_keep_set():
    # probs (.5, .3, .15, .05): p=0.75 keeps exactly the top-2 prefix
    # (first prefix whose mass reaches 0.75; off the cumsum boundary so
    # float rounding can't flip the keep set)
    probs = np.array([0.5, 0.3, 0.15, 0.05])
    shaped = np.asarray(shape_logits(jnp.log(probs)[None], 1.0, 0.75))[0]
    assert np.isfinite(shaped[:2]).all()
    assert np.isneginf(shaped[2:]).all()
    # p >= 1 is a no-op: nothing truncated
    full = np.asarray(shape_logits(jnp.log(probs)[None], 1.0, 1.0))[0]
    assert np.isfinite(full).all()


def test_shape_logits_top_p_always_keeps_top1():
    probs = np.array([0.9, 0.06, 0.04])
    shaped = np.asarray(shape_logits(jnp.log(probs)[None], 1.0, 1e-6))[0]
    assert np.isfinite(shaped[0])
    assert np.isneginf(shaped[1:]).all()


def test_shape_logits_per_row_controls():
    # (B,) temperature / top_p broadcast over (B, V) rows independently
    probs = np.array([[0.5, 0.3, 0.15, 0.05]] * 2)
    shaped = np.asarray(shape_logits(jnp.log(probs),
                                     jnp.asarray([1.0, 2.0]),
                                     jnp.asarray([0.75, 1.0])))
    assert np.isneginf(shaped[0, 2:]).all()      # row 0 truncated at p=.75
    assert np.isfinite(shaped[1]).all()          # row 1 untouched (p=1)
    np.testing.assert_allclose(shaped[1], np.log(probs[1]) / 2.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# residual_pmf: the rejection residual is p conditioned on t != x
# ---------------------------------------------------------------------------
def test_residual_pmf_zeroes_rejected_and_renormalizes():
    probs = jnp.asarray([[0.5, 0.3, 0.2]])
    res = np.asarray(residual_pmf(probs, jnp.asarray([0])))[0]
    assert res[0] == 0.0
    np.testing.assert_allclose(res.sum(), 1.0, rtol=1e-6)
    # surviving entries keep their relative proportions (0.3 : 0.2)
    np.testing.assert_allclose(res[1] / res[2], 1.5, rtol=1e-5)


# ---------------------------------------------------------------------------
# per_row_keys / sample_predictions: the trajectory-coupling sampler
# ---------------------------------------------------------------------------
def test_per_row_keys_expand_and_passthrough():
    base = jax.random.PRNGKey(3)
    keys = per_row_keys(base, 4)
    assert keys.shape == (4, 2)
    assert len({tuple(np.asarray(k)) for k in keys}) == 4   # all distinct
    np.testing.assert_array_equal(np.asarray(per_row_keys(keys, 4)),
                                  np.asarray(keys))         # (B,2) untouched


def test_sample_predictions_temp0_is_argmax_bitexact():
    logits = jax.random.normal(jax.random.PRNGKey(0), (3, 2, 4, 16))
    rng = per_row_keys(jax.random.PRNGKey(1), 3)
    preds = sample_predictions(logits, rng, jnp.zeros((3,)), jnp.ones((3,)))
    np.testing.assert_array_equal(
        np.asarray(preds), np.asarray(jnp.argmax(logits, axis=-1)))


def test_sample_predictions_rows_share_level_noise():
    # identical logits in different draft rows at the same level MUST give
    # identical samples — one trajectory per slot is the whole point
    row = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 4, 32))
    logits = jnp.concatenate([row, row], axis=1)            # (1, 2, 4, V)
    rng = per_row_keys(jax.random.PRNGKey(7), 1)
    preds = np.asarray(sample_predictions(
        logits, rng, jnp.ones((1,)) * 1.5, jnp.ones((1,))))
    np.testing.assert_array_equal(preds[:, 0], preds[:, 1])
    # ...but DIFFERENT levels draw fresh noise: with identical flat-ish
    # logits replicated across levels, the per-level samples must not all
    # collapse to one token (seed-pinned, deterministic)
    flat = jnp.broadcast_to(row[:, :, :1], row.shape)       # same logits / lv
    p2 = np.asarray(sample_predictions(
        flat, rng, jnp.ones((1,)) * 3.0, jnp.ones((1,))))
    assert len(set(p2[0, 0].tolist())) > 1


def test_sample_predictions_levels_map_shares_noise():
    # tree mode hands a levels map: positions with the SAME level (sibling
    # nodes) share noise, so equal logits => equal samples across them
    row = jax.random.normal(jax.random.PRNGKey(4), (1, 1, 1, 32))
    logits = jnp.broadcast_to(row, (1, 1, 3, 32))
    rng = per_row_keys(jax.random.PRNGKey(9), 1)
    preds = np.asarray(sample_predictions(
        logits, rng, jnp.ones((1,)) * 2.0, jnp.ones((1,)),
        levels=np.asarray([0, 0, 1])))
    assert preds[0, 0, 0] == preds[0, 0, 1]                 # same level
    t0 = np.asarray(sample_predictions(
        logits, rng, jnp.zeros((1,)), jnp.ones((1,)),
        levels=np.asarray([0, 0, 1])))
    np.testing.assert_array_equal(t0, np.asarray(jnp.argmax(logits, -1)))


def test_sample_token_mixed_rows():
    logits = jax.random.normal(jax.random.PRNGKey(5), (4, 32))
    rng = per_row_keys(jax.random.PRNGKey(6), 4)
    temp = jnp.asarray([0.0, 0.0, 1.0, 1.0])
    tok = np.asarray(sample_token(logits, rng, temp, jnp.ones((4,))))
    am = np.asarray(jnp.argmax(logits, axis=-1))
    np.testing.assert_array_equal(tok[:2], am[:2])          # greedy rows
    assert tok.dtype == np.int32 and (0 <= tok).all() and \
        (tok < 32).all()


# ---------------------------------------------------------------------------
# satellite: serving.sampling.temperature_sample
# ---------------------------------------------------------------------------
def test_temperature_sample_negative_raises():
    with pytest.raises(ValueError, match="temperature"):
        temperature_sample(jax.random.PRNGKey(0),
                           jnp.zeros((2, 8)), temperature=-0.5)


def test_temperature_sample_zero_is_greedy():
    logits = jax.random.normal(jax.random.PRNGKey(1), (3, 16))
    out = temperature_sample(jax.random.PRNGKey(0), logits, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_temperature_sample_upcasts_half_precision():
    # f16 logits / 1e-3 overflows f16 (both large entries -> inf -> the
    # categorical breaks ties arbitrarily); the upcast keeps the ordering,
    # so a sharp distribution must ALWAYS return its argmax
    logits = jnp.asarray([[400.0, 500.0, -10.0]] * 8, jnp.float16)
    out = np.asarray(temperature_sample(jax.random.PRNGKey(2), logits,
                                        temperature=1e-3))
    assert (out == 1).all()


def test_temperature_sample_top_p_truncates():
    # top_p small enough keeps only the top token -> draws are deterministic
    logits = jnp.log(jnp.asarray([[0.5, 0.3, 0.2]] * 16))
    out = np.asarray(temperature_sample(jax.random.PRNGKey(3), logits,
                                        temperature=1.0, top_p=0.4))
    assert (out == 0).all()


# ---------------------------------------------------------------------------
# temperature == 0 bit-parity: sampling-enabled steps stay exactly greedy
# ---------------------------------------------------------------------------
def _sampled_spec(strategy, backend="xla", **kw):
    return SpecConfig(k=4, w=3, strategy=strategy, max_new_tokens=12,
                      backend=backend, sampling=True, **kw)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_temp0_bit_parity_strategies(model, tables, strategy):
    cfg, params = model
    prompt = _prompt(cfg)
    P, N = prompt.shape[1], 12
    ref = greedy_reference(params, cfg, prompt, N)
    buf, blen, _ = generate(params, cfg, _sampled_spec(strategy), prompt,
                            tables, temperature=0.0, top_p=1.0,
                            rng=jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(buf[:, :P + N]),
                                  np.asarray(ref))
    assert (np.asarray(blen) == P + N).all()


@pytest.mark.parametrize("paged", [False, True], ids=["linear", "paged"])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_temp0_bit_parity_backend_layout(model, tables, backend, paged):
    cfg, params = model
    cfg = dataclasses.replace(cfg, backend=backend).validate()
    prompt = _prompt(cfg)
    P, N = prompt.shape[1], 8
    ref = greedy_reference(params, cfg, prompt, N)
    spec = dataclasses.replace(_sampled_spec("mixed", backend),
                               max_new_tokens=N)
    buf, _, _ = generate(params, cfg, spec, prompt, tables,
                         temperature=0.0, rng=jax.random.PRNGKey(7),
                         paged=PagedConfig(page_size=16) if paged else None)
    np.testing.assert_array_equal(np.asarray(buf[:, :P + N]),
                                  np.asarray(ref))


def test_temp0_bit_parity_arms_and_tree(model, tables):
    cfg, params = model
    prompt = _prompt(cfg)
    P, N = prompt.shape[1], 12
    ref = greedy_reference(params, cfg, prompt, N)
    for spec in (_sampled_spec("mixed", arms=((1, 0), (2, 2), (4, 3))),
                 _sampled_spec("mixed", tree=True, tree_branch=2)):
        buf, _, _ = generate(params, cfg, spec, prompt, tables,
                             temperature=0.0, rng=jax.random.PRNGKey(7))
        np.testing.assert_array_equal(np.asarray(buf[:, :P + N]),
                                      np.asarray(ref), err_msg=str(spec))


def test_mixed_rows_greedy_rows_unperturbed(model, tables):
    # per-row temperature: row 0 greedy, row 1 sampled — row 0 must stay
    # bit-exact even though it shares the verify call with a sampled row
    cfg, params = model
    prompt = _prompt(cfg)
    P, N = prompt.shape[1], 12
    ref = greedy_reference(params, cfg, prompt, N)
    buf, blen, _ = generate(params, cfg, _sampled_spec("mixed"), prompt,
                            tables, temperature=jnp.asarray([0.0, 0.9]),
                            rng=jax.random.PRNGKey(11))
    np.testing.assert_array_equal(np.asarray(buf[0, :P + N]),
                                  np.asarray(ref[0]))
    assert (np.asarray(blen) == P + N).all()


def test_sampling_args_without_flag_raise(model, tables):
    cfg, params = model
    spec = SpecConfig(k=4, w=3, strategy="mixed", max_new_tokens=4)
    with pytest.raises(ValueError, match="sampling"):
        init_decode_state(params, cfg, spec, _prompt(cfg), temperature=0.7)


def test_sampled_generate_replays_and_varies(model, tables):
    cfg, params = model
    prompt = _prompt(cfg)
    P, N = prompt.shape[1], 12
    runs = [np.asarray(generate(params, cfg, _sampled_spec("mixed"), prompt,
                                tables, temperature=0.9,
                                rng=jax.random.PRNGKey(s))[0][:, :P + N])
            for s in (0, 0, 1)]
    np.testing.assert_array_equal(runs[0], runs[1])   # same key replays
    assert (runs[0] != runs[2]).any()                 # fresh key varies


# ---------------------------------------------------------------------------
# satellite: accept_hist invariant (bin 0 structurally zero, sum == calls)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", ["mixed", "greedy"])
@pytest.mark.parametrize("temp", [0.0, 0.9], ids=["greedy-t", "sampled-t"])
def test_accept_hist_accounts_every_call(model, tables, strategy, temp):
    cfg, params = model
    spec = _sampled_spec(strategy)
    _, _, stats = generate(params, cfg, spec, _prompt(cfg), tables,
                           temperature=temp, rng=jax.random.PRNGKey(3))
    hist = np.asarray(stats["accept_hist"])
    calls = np.asarray(stats["calls"])
    assert (hist[:, 0] == 0).all()                    # canary bin
    np.testing.assert_array_equal(hist.sum(axis=1), calls)
    if strategy == "greedy":
        # plain-greedy body books its single committed token into bin 1
        np.testing.assert_array_equal(hist[:, 1], calls)
    assert (calls > 0).all()


# ---------------------------------------------------------------------------
# satellite: eos/budget retirement around the bonus token
# ---------------------------------------------------------------------------
def test_eos_exactly_at_budget_no_overshoot(model, tables):
    # eos = the trajectory's token at position budget-1: the finishing
    # token is committed (possibly as the call's bonus), the row retires
    # with EXACTLY budget tokens, and the prefix matches greedy
    cfg, params = model
    prompt = _prompt(cfg, B=1)
    P = prompt.shape[1]
    ref = np.asarray(greedy_reference(params, cfg, prompt, 12))
    for budget in (1, 2, 3, 5, 8):
        eos = int(ref[0, P + budget - 1])
        first = int(np.argmax(ref[0, P:P + 12] == eos))   # first occurrence
        spec = dataclasses.replace(_sampled_spec("mixed"),
                                   max_new_tokens=budget)
        buf, blen, _ = generate(params, cfg, spec, prompt, tables,
                                temperature=0.0, rng=jax.random.PRNGKey(7),
                                eos_id=jnp.asarray([eos]))
        got = int(blen[0]) - P
        want = min(first + 1, budget)
        assert got == want, (budget, eos, got, want)
        np.testing.assert_array_equal(np.asarray(buf[0, P:P + got]),
                                      ref[0, P:P + got])
        assert got <= budget                              # never overshoots


def test_eos_mid_stream_sampled_stops_once(model, tables):
    # sampled rows also stop at their first eos and never exceed budget —
    # the retirement edges hold when commits come from the sampled walk
    cfg, params = model
    prompt = _prompt(cfg, B=4)
    P, N = prompt.shape[1], 16
    rng = jax.random.PRNGKey(21)
    ref = np.asarray(sampling_reference(params, cfg, prompt, N, rng, 0.9))
    # distributions match but trajectories don't (different key schedules),
    # so derive eos per row from the SPEC run itself: run once eos-free,
    # then re-run with eos = an emitted token and check the cut
    spec = dataclasses.replace(_sampled_spec("mixed"), max_new_tokens=N)
    buf0, len0, _ = generate(params, cfg, spec, prompt, tables,
                             temperature=0.9, rng=rng)
    free = np.asarray(buf0)
    eos = np.asarray([free[b, P + 5] for b in range(4)], np.int32)
    buf1, len1, _ = generate(params, cfg, spec, prompt, tables,
                             temperature=0.9, rng=rng,
                             eos_id=jnp.asarray(eos))
    for b in range(4):
        got = int(len1[b]) - P
        assert got <= N
        first = int(np.argmax(free[b, P:P + N] == eos[b]))
        assert got == first + 1, (b, got, first)
        np.testing.assert_array_equal(np.asarray(buf1[b, P:P + got]),
                                      free[b, P:P + got])
        assert int(buf1[b, P + got - 1]) == int(eos[b])
    assert ref.shape == (4, P + N)                    # oracle sanity


# ---------------------------------------------------------------------------
# ServingEngine: mixed continuous batches, one trace, rejection, replay
# ---------------------------------------------------------------------------
def _mk_engine(model, tables, name, **kw):
    cfg, params = model
    cfg = dataclasses.replace(cfg, name=name).validate()
    spec = SpecConfig(k=4, w=3, strategy="mixed", max_new_tokens=16)
    return ServingEngine(params, cfg, spec, tables=tables, max_batch=4,
                         buckets=(16,), max_new_cap=16, **kw), cfg, params


def test_engine_mixed_continuous_lossless_and_replayable(model, tables):
    eng, cfg, params = _mk_engine(model, tables, "sampling-mixed")
    g1 = eng.submit("hello world", max_new_tokens=12)
    s1 = eng.submit("sampled req a", max_new_tokens=12, temperature=0.8,
                    seed=11)
    g2 = eng.submit("another greedy", max_new_tokens=9)
    s2 = eng.submit("sampled req b", max_new_tokens=12, temperature=1.1,
                    top_p=0.9, seed=12)
    done = {r.request_id: r for r in eng.serve_continuous()}
    assert eng.sampling is True       # auto-resolved from queued requests
    # greedy rows: bit-exact vs the pure-greedy oracle, untouched by the
    # sampled rows sharing their verify calls
    for req in (g1, g2):
        padded = eng.scheduler.pad_to_bucket(eng.tok.encode(req.prompt))
        ref = greedy_reference(params, cfg, jnp.asarray(padded)[None],
                               req.max_new_tokens)
        np.testing.assert_array_equal(
            done[req.request_id].output_ids,
            np.asarray(ref[0, len(padded):]), err_msg=req.prompt)
    # sampled rows: pinned seeds replay bit-identically on a FRESH engine
    eng2, _, _ = _mk_engine(model, tables, "sampling-mixed")
    r1 = eng2.submit(s1.prompt, max_new_tokens=12, temperature=0.8, seed=11)
    r2 = eng2.submit(s2.prompt, max_new_tokens=12, temperature=1.1,
                     top_p=0.9, seed=12)
    redo = {r.request_id: r for r in eng2.serve_continuous()}
    np.testing.assert_array_equal(done[s1.request_id].output_ids,
                                  redo[r1.request_id].output_ids)
    np.testing.assert_array_equal(done[s2.request_id].output_ids,
                                  redo[r2.request_id].output_ids)
    for req in (s1, s2):
        st = done[req.request_id].stats
        assert st["model_calls"] > 0 and "error" not in st


def test_engine_mixed_continuous_compiles_step_once(model, tables,
                                                    monkeypatch):
    """The whole mixed greedy/sampled workload runs through ONE step trace."""
    cfg, params = model
    cfg = dataclasses.replace(cfg, name="sampling-spy").validate()
    traces = {"n": 0}
    real = spec_engine._step_body

    def spy(*a, **kw):
        traces["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(spec_engine, "_step_body", spy)
    spec = SpecConfig(k=4, w=3, strategy="mixed", max_new_tokens=12)
    eng = ServingEngine(params, cfg, spec, tables=tables, max_batch=2,
                        buckets=(16,), max_new_cap=12)
    eng.submit("greedy row", max_new_tokens=10)
    eng.submit("sampled row", max_new_tokens=10, temperature=0.9, seed=5)
    eng.step()
    eng.submit("late sampled", max_new_tokens=8, temperature=1.2, seed=6)
    done = eng.serve_continuous()
    assert len(done) == 3
    assert all("error" not in r.stats for r in done)
    assert traces["n"] == 1


def test_engine_pinned_greedy_rejects_sampled_admission(model, tables):
    # sampling=False pins the pre-sampling greedy-only executable; a
    # sampled request must be REJECTED at admission with a clear message,
    # not silently decoded greedy (that would be a losslessness lie)
    eng, _, _ = _mk_engine(model, tables, "sampling-pinned", sampling=False)
    ok = eng.submit("greedy fine", max_new_tokens=8)
    bad = eng.submit("sampled not", max_new_tokens=8, temperature=0.7)
    done = {r.request_id: r for r in eng.serve_continuous()}
    assert done[ok.request_id].output_ids is not None
    assert "error" in done[bad.request_id].stats
    assert "sampling" in done[bad.request_id].stats["error"]


def test_engine_submit_validation(model, tables):
    eng, _, _ = _mk_engine(model, tables, "sampling-validate")
    with pytest.raises(ValueError, match="temperature"):
        eng.submit("x", temperature=-0.1)
    with pytest.raises(ValueError, match="top_p"):
        eng.submit("x", top_p=0.0)
    with pytest.raises(ValueError, match="top_p"):
        eng.submit("x", top_p=1.5)


def test_engine_static_batch_sampled(model, tables):
    # serve_all (static batching) threads the same controls: greedy
    # requests match the oracle, seeded sampled requests replay
    outs = []
    for _ in range(2):
        eng, cfg, params = _mk_engine(model, tables, "sampling-static")
        g = eng.submit("static greedy", max_new_tokens=10)
        s = eng.submit("static sample", max_new_tokens=10, temperature=0.9,
                       seed=4)
        done = {r.request_id: r for r in eng.serve_all()}
        padded = eng.scheduler.pad_to_bucket(eng.tok.encode(g.prompt))
        ref = greedy_reference(params, cfg, jnp.asarray(padded)[None], 10)
        np.testing.assert_array_equal(done[g.request_id].output_ids,
                                      np.asarray(ref[0, len(padded):]))
        outs.append(done[s.request_id].output_ids)
    np.testing.assert_array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# distributional parity: spec-path sampling == plain autoregressive
# sampling, in distribution (slow: hundreds of rows through both paths)
# ---------------------------------------------------------------------------
def _tv(a_counts, b_counts):
    pa = a_counts / a_counts.sum()
    pb = b_counts / b_counts.sum()
    return 0.5 * np.abs(pa - pb).sum()


def _chi2_two_sample(a_counts, b_counts, min_expected=5.0):
    """Two-sample chi-square with tail-merged cells (expected >= 5)."""
    tot = a_counts + b_counts
    order = np.argsort(tot)[::-1]
    a, b = a_counts[order].astype(float), b_counts[order].astype(float)
    # merge the sparse tail into one cell
    keep = np.cumsum((a + b) < 2 * min_expected) == 0
    k = max(int(keep.sum()), 1)
    a = np.concatenate([a[:k], [a[k:].sum()]])
    b = np.concatenate([b[:k], [b[k:].sum()]])
    na, nb = a.sum(), b.sum()
    p = (a + b) / (na + nb)
    ea, eb = na * p, nb * p
    mask = (ea > 0) & (eb > 0)
    stat = (((a - ea) ** 2 / np.where(mask, ea, 1.0))[mask].sum()
            + (((b - eb) ** 2 / np.where(mask, eb, 1.0))[mask]).sum())
    return stat, int(mask.sum()) - 1


@pytest.mark.slow
@pytest.mark.parametrize("temp,topp", [(0.9, 1.0), (1.2, 0.8)],
                         ids=["t0.9", "t1.2-p0.8"])
def test_spec_sampling_matches_plain_distribution(temp, topp):
    """B=512 rows: per-position marginals of the spec walk vs the plain
    sampler agree (TV below the measured same-sampler noise floor), and a
    mismatched-temperature control shows the test has power.  The spec run
    must also actually SPECULATE (commit > 1 token on a real fraction of
    calls) — otherwise it degenerates to the plain sampler and the parity
    claim is vacuous."""
    V = 17
    cfg = ModelConfig(name="tv", num_layers=2, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=V, **F32
                      ).validate()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    fwd = jax.jit(lambda t: M.forward(params, cfg, tokens=t)[0][:, -1])
    topk, chain = build_bigram(fwd, V, k_max=4, w_max=4, batch=V)
    uni = build_unigram(params["embed"]["embedding"],
                        params["embed"]["lm_head"], k_max=4)
    tabs = NGramTables(uni, topk, chain)
    B, N = 512, 4
    prompt = jnp.broadcast_to(jnp.asarray([3, 1, 4, 1, 5, 9]), (B, 6))
    P = prompt.shape[1]
    spec = SpecConfig(k=4, w=3, strategy="mixed", max_new_tokens=N,
                      sampling=True)
    buf, _, stats = generate(params, cfg, spec, prompt, tabs,
                             temperature=temp, top_p=topp,
                             rng=jax.random.PRNGKey(17))
    spec_toks = np.asarray(buf[:, P:P + N])
    ref = np.asarray(sampling_reference(params, cfg, prompt, N,
                                        jax.random.PRNGKey(170), temp,
                                        topp))[:, P:P + N]
    ctl = np.asarray(sampling_reference(params, cfg, prompt, N,
                                        jax.random.PRNGKey(171), 0.3,
                                        1.0))[:, P:P + N]
    for pos in range(N):
        cs = np.bincount(spec_toks[:, pos], minlength=V)
        cr = np.bincount(ref[:, pos], minlength=V)
        # matched: below the measured same-sampler noise floor at B=512
        assert _tv(cs, cr) < 0.18, (pos, _tv(cs, cr))
        stat, df = _chi2_two_sample(cs, cr)
        assert stat < df + 6 * np.sqrt(2 * max(df, 1)), (pos, stat, df)
    # power: a 0.3-temperature control is clearly distinguishable
    cc = np.bincount(ctl[:, 0], minlength=V)
    c0 = np.bincount(spec_toks[:, 0], minlength=V)
    assert _tv(c0, cc) > 0.25, _tv(c0, cc)
    # the walk really speculates: > 1 token committed on >= 10% of calls
    hist = np.asarray(stats["accept_hist"]).sum(axis=0)
    calls = int(np.asarray(stats["calls"]).sum())
    assert hist[2:].sum() / calls > 0.10
    assert hist[0] == 0 and hist.sum() == calls


@pytest.mark.slow
def test_residual_pmf_property():
    """Hypothesis: the residual is exactly p conditioned on t != rejected."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=50, deadline=None)
    @given(st.integers(2, 12).flatmap(
        lambda v: st.tuples(
            st.lists(st.floats(-3, 3), min_size=v, max_size=v),
            st.integers(0, v - 1))))
    def check(case):
        logits, rejected = case
        probs = jax.nn.softmax(jnp.asarray(logits, jnp.float32))
        res = np.asarray(residual_pmf(probs[None],
                                      jnp.asarray([rejected])))[0]
        assert res[rejected] == 0.0
        assert (res >= 0).all()
        np.testing.assert_allclose(res.sum(), 1.0, rtol=1e-5)
        # proportionality: res == probs / (1 - probs[rejected]) off the hit
        p = np.asarray(probs)
        keep = np.arange(len(p)) != rejected
        np.testing.assert_allclose(res[keep],
                                   p[keep] / (1.0 - p[rejected]),
                                   rtol=1e-4)

    check()
