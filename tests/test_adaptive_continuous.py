"""In-flight adaptive (k, w) via shape-stable arm masking (DESIGN.md §9).

The contract under test: a slot running arm (k_b, w_b) inside a
(k_max, w_max)-shaped ``spec_step`` accepts and commits EXACTLY what a
dedicated static (k_b, w_b) run would — bit-parity per arm, for every
drafting strategy, on both kernel backends (pallas in interpret mode), for
both the one-shot ``generate()`` and the continuous ``spec_step`` drive,
over linear and paged KV layouts.  Greedy decoding is the (1, 0) arm of the
same masked step, so "all 5 strategies" are covered with four drafting
strategies x the greedy arm.

Also pinned here: the ServingEngine adaptive continuous path (the former
``NotImplementedError`` branch) is gone — it serves losslessly, reports
per-request arm pulls, and compiles the step EXACTLY once for the whole
arm table (the compile-count spy).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spec_engine
from repro.core.ngram_tables import NGramTables, build_bigram, build_unigram
from repro.core.spec_engine import (PagedConfig, SpecConfig, admit_slot,
                                    empty_decode_state, generate,
                                    greedy_reference, init_decode_state,
                                    spec_step)
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving import ServingEngine

F32 = dict(param_dtype=jnp.float32, compute_dtype=jnp.float32)

# the masked box is (K_MAX, W_MAX); every arm is strictly inside it on at
# least one axis, so masking (not shape equality) is what's being tested
K_MAX, W_MAX = 4, 3
ARMS = [(1, 0), (2, 2), (3, 1), (4, 3)]


@pytest.fixture(scope="module")
def model():
    """Kernel-eligible tiny arch (small block so pallas interpret is fast)."""
    cfg = ModelConfig(name="adapt", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=61,
                      backend="xla", kernel_block_s=16, **F32).validate()
    return cfg, M.init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def tables(model):
    cfg, params = model
    fwd = jax.jit(lambda t: M.forward(params, cfg, tokens=t)[0][:, -1])
    topk, chain = build_bigram(fwd, cfg.vocab_size, k_max=8, w_max=8,
                               batch=cfg.vocab_size)
    uni = build_unigram(params["embed"]["embedding"],
                        params["embed"]["lm_head"], k_max=8)
    return NGramTables(uni, topk, chain)


def _masked_spec(strategy, arm, backend="xla"):
    return SpecConfig(k=K_MAX, w=W_MAX, strategy=strategy, max_new_tokens=20,
                      arms=(arm,), backend=backend)


def _dedicated_spec(strategy, arm, backend="xla"):
    """The static run the masked arm must reproduce; (1, 0) IS greedy."""
    k, w = arm
    if w == 0:
        return SpecConfig(strategy="greedy", max_new_tokens=20,
                          backend=backend)
    return SpecConfig(k=k, w=w, strategy=strategy, max_new_tokens=20,
                      backend=backend)


def _prompt(cfg, B=2, P=10, seed=5):
    return jax.random.randint(jax.random.PRNGKey(seed), (B, P), 0,
                              cfg.vocab_size)


def _drive(params, cfg, spec, state, tables, max_steps=100):
    for _ in range(max_steps):
        if not bool(np.asarray(~state.done).any()):
            return state
        state = spec_step(params, cfg, spec, state, tables)
    raise AssertionError("spec_step did not converge")


# ---------------------------------------------------------------------------
# generate(): every arm x every drafting strategy (greedy == the (1,0) arm)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arm", ARMS, ids=lambda a: f"k{a[0]}w{a[1]}")
@pytest.mark.parametrize("strategy", ["bigram", "unigram", "context",
                                      "mixed"])
def test_generate_masked_arm_parity(model, tables, strategy, arm):
    cfg, params = model
    prompt = _prompt(cfg)
    P, N = prompt.shape[1], 20
    buf_m, len_m, _ = generate(params, cfg, _masked_spec(strategy, arm),
                               prompt, tables)
    buf_d, len_d, _ = generate(params, cfg, _dedicated_spec(strategy, arm),
                               prompt, tables)
    np.testing.assert_array_equal(np.asarray(len_m), np.asarray(len_d))
    np.testing.assert_array_equal(np.asarray(buf_m[:, :P + N]),
                                  np.asarray(buf_d[:, :P + N]))


# ---------------------------------------------------------------------------
# continuous spec_step drive (admit_slot into a shared state):
# arm x strategy on xla, arm x backend on the mixed strategy
# ---------------------------------------------------------------------------
def _step_parity(model, tables, strategy, arm, backend):
    cfg, params = model
    cfg = dataclasses.replace(cfg, backend=backend).validate()
    prompt = _prompt(cfg)
    B, P, N = prompt.shape[0], prompt.shape[1], 12
    outs = {}
    for mode in ("masked", "dedicated"):
        spec = (_masked_spec(strategy, arm, backend) if mode == "masked"
                else _dedicated_spec(strategy, arm, backend))
        spec = dataclasses.replace(spec, max_new_tokens=N)
        state = empty_decode_state(cfg, spec, B, P + N + spec.w + 2)
        # staggered admission: slot 1 arrives one step late (slot reuse of
        # the admit/spec_step jits, exactly the serving drive)
        state = admit_slot(params, cfg, state, jnp.int32(0), prompt[0],
                           jnp.int32(N), jnp.int32(-1))
        state = spec_step(params, cfg, spec, state, tables)
        state = admit_slot(params, cfg, state, jnp.int32(1), prompt[1],
                           jnp.int32(N), jnp.int32(-1))
        state = _drive(params, cfg, spec, state, tables)
        outs[mode] = np.asarray(state.buf[:, :P + N])
        assert (np.asarray(state.buf_len) == P + N).all()
    np.testing.assert_array_equal(outs["masked"], outs["dedicated"])


@pytest.mark.parametrize("arm", ARMS, ids=lambda a: f"k{a[0]}w{a[1]}")
@pytest.mark.parametrize("strategy", ["bigram", "unigram", "context",
                                      "mixed"])
def test_step_masked_arm_parity(model, tables, strategy, arm):
    _step_parity(model, tables, strategy, arm, "xla")


@pytest.mark.parametrize("arm", ARMS, ids=lambda a: f"k{a[0]}w{a[1]}")
def test_step_masked_arm_parity_pallas(model, tables, arm):
    """Interpret-mode pallas on the strategy that exercises BOTH kernels
    (context sweep + verify attention); the xla sweep above covers the
    strategy axis."""
    _step_parity(model, tables, "mixed", arm, "pallas")


# ---------------------------------------------------------------------------
# paged KV layout: the masked arm must match the dedicated PAGED run too
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arm", ARMS, ids=lambda a: f"k{a[0]}w{a[1]}")
def test_paged_masked_arm_parity(model, tables, arm):
    cfg, params = model
    prompt = _prompt(cfg)
    P, N = prompt.shape[1], 16
    paged = PagedConfig(page_size=16)
    buf_m, len_m, _ = generate(params, cfg, _masked_spec("mixed", arm),
                               prompt, tables, paged=paged)
    buf_d, len_d, _ = generate(params, cfg, _dedicated_spec("mixed", arm),
                               prompt, tables, paged=paged)
    np.testing.assert_array_equal(np.asarray(len_m), np.asarray(len_d))
    np.testing.assert_array_equal(np.asarray(buf_m[:, :P + N]),
                                  np.asarray(buf_d[:, :P + N]))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_paged_adaptive_step_lossless(model, tables, backend):
    """Full multi-arm table over the paged continuous drive: adaptation on
    a shared page pool stays lossless on both backends."""
    cfg, params = model
    cfg = dataclasses.replace(cfg, backend=backend).validate()
    prompt = _prompt(cfg)
    B, P, N = prompt.shape[0], prompt.shape[1], 12
    ref = greedy_reference(params, cfg, prompt, N)
    spec = SpecConfig(k=K_MAX, w=W_MAX, strategy="mixed", max_new_tokens=N,
                      arms=tuple(ARMS), backend=backend)
    state = init_decode_state(params, cfg, spec, prompt,
                              paged=PagedConfig(page_size=16))
    state = _drive(params, cfg, spec, state, tables)
    np.testing.assert_array_equal(np.asarray(state.buf[:, :P + N]),
                                  np.asarray(ref))


# ---------------------------------------------------------------------------
# full arm table: adaptation is lossless and the bandit state behaves
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_adaptive_generate_lossless(model, tables, backend):
    cfg, params = model
    cfg = dataclasses.replace(cfg, backend=backend).validate()
    prompt = _prompt(cfg)
    P, N = prompt.shape[1], 20
    ref = greedy_reference(params, cfg, prompt, N)
    spec = SpecConfig(k=K_MAX, w=W_MAX, strategy="mixed", max_new_tokens=N,
                      arms=tuple(ARMS), backend=backend)
    buf, blen, stats = generate(params, cfg, spec, prompt, tables)
    np.testing.assert_array_equal(np.asarray(buf[:, :P + N]),
                                  np.asarray(ref))
    pulls = np.asarray(stats["arm_pulls"])
    assert pulls.shape == (prompt.shape[0], len(ARMS))
    # every slot pulled each arm at least once before exploiting (UCB
    # optimistic init), and pulls account for every verify call
    assert (pulls > 0).all()
    np.testing.assert_array_equal(pulls.sum(axis=1),
                                  np.asarray(stats["calls"]))


def test_arm_table_validation(model):
    cfg, params = model
    prompt = _prompt(cfg)
    for bad in [((5, 3),), ((0, 2),), ((2, 4),), ()]:
        with pytest.raises(ValueError):
            generate(params, cfg,
                     SpecConfig(k=K_MAX, w=W_MAX, strategy="mixed",
                                max_new_tokens=4, arms=bad), prompt)
    with pytest.raises(ValueError):
        generate(params, cfg,
                 SpecConfig(k=K_MAX, w=W_MAX, strategy="greedy",
                            max_new_tokens=4, arms=((1, 0),)), prompt)


# ---------------------------------------------------------------------------
# ServingEngine: the former NotImplementedError branch now serves, once-
# compiled, with per-request bandit stats (regression for the removed error)
# ---------------------------------------------------------------------------
def _reference_ids(eng, params, cfg, prompt: str, max_new: int):
    padded = eng.scheduler.pad_to_bucket(eng.tok.encode(prompt))[None]
    ref = greedy_reference(params, cfg, jnp.asarray(padded), max_new)
    return np.asarray(ref[0, padded.shape[1]:], np.int32)


def test_engine_adaptive_continuous_serves_lossless(model):
    """adaptive=True + serve_continuous() must WORK (the documented
    NotImplementedError + masking-workaround message is gone) and stay
    bit-lossless per request while adapting per slot."""
    cfg, params = model
    spec = SpecConfig(k=4, w=3, strategy="mixed", max_new_tokens=16)
    eng = ServingEngine(params, cfg, spec, max_batch=2, adaptive=True,
                        arms=tuple(ARMS), buckets=(16,), max_new_cap=16)
    r1 = eng.submit("hello world", max_new_tokens=16)
    r2 = eng.submit("a rather different prompt", max_new_tokens=9)
    for _ in range(2):
        eng.step()                      # must not raise (old error branch)
    r3 = eng.submit("late arrival", max_new_tokens=12)
    done = eng.serve_continuous()
    reqs = {r.request_id: r for r in done}
    assert sorted(reqs) == sorted(r.request_id for r in (r1, r2, r3))
    for req in (r1, r2, r3):
        expect = _reference_ids(eng, params, cfg, req.prompt,
                                req.max_new_tokens)
        np.testing.assert_array_equal(reqs[req.request_id].output_ids,
                                      expect, err_msg=req.prompt)
        # each retired request carries its own bandit history, and the
        # pulls add up to its verify calls
        pulls = reqs[req.request_id].stats["arm_pulls"]
        assert sum(pulls.values()) == \
            reqs[req.request_id].stats["model_calls"]
    agg = eng.adaptive_stats()
    assert agg["arms"] == [list(a) for a in ARMS]
    assert sum(agg["pulls_retired"]) == \
        sum(r.stats["model_calls"] for r in done)


def test_engine_adaptive_compiles_step_exactly_once(model, monkeypatch):
    """One spec_step compilation per buffer shape for the WHOLE arm table:
    arm switching happens inside the jit, so driving an adaptive engine
    through many steps (with every arm demonstrably pulled) must trace the
    step body exactly once."""
    cfg, params = model
    cfg = dataclasses.replace(cfg, name="adapt-spy").validate()  # fresh jit
    traces = {"n": 0}
    real = spec_engine._step_body

    def spy(*a, **k):
        traces["n"] += 1
        return real(*a, **k)

    monkeypatch.setattr(spec_engine, "_step_body", spy)
    spec = SpecConfig(k=4, w=3, strategy="mixed", max_new_tokens=12)
    eng = ServingEngine(params, cfg, spec, max_batch=2, adaptive=True,
                        arms=tuple(ARMS), buckets=(16,), max_new_cap=12)
    for p in ["one", "two", "three", "four"]:
        eng.submit(p, max_new_tokens=12)
    done = eng.serve_continuous()
    assert len(done) == 4
    pulled = np.asarray(eng.adaptive_stats()["pulls_retired"])
    assert (pulled > 0).all(), "every arm must actually have been pulled"
    assert traces["n"] == 1, (
        f"spec_step traced {traces['n']} times across arm switches — "
        f"per-arm recompilation defeats shape-stable masking")


def test_engine_adaptive_paged_continuous(model):
    """Adaptive arms over the paged pool: reservation sizes for the worst
    arm, serving stays lossless, and no pages leak."""
    cfg, params = model
    spec = SpecConfig(k=4, w=3, strategy="mixed", max_new_tokens=12)
    eng = ServingEngine(params, cfg, spec, max_batch=2, adaptive=True,
                        arms=tuple(ARMS), buckets=(16,), max_new_cap=12,
                        paged=True, page_size=16)
    reqs = [eng.submit(p, max_new_tokens=12)
            for p in ["paged one", "paged two", "paged three"]]
    done = eng.serve_continuous()
    assert len(done) == 3
    for req in reqs:
        expect = _reference_ids(eng, params, cfg, req.prompt, 12)
        got = next(r for r in done if r.request_id == req.request_id)
        np.testing.assert_array_equal(got.output_ids, expect)
    pool = eng.pool_stats()
    assert pool["free_pages"] == pool["num_pages"], f"leak: {pool}"


def test_slot_reuse_resets_bandit(model, tables):
    """A reused slot must restart exploration: request N+1's per-arm pulls
    cannot include request N's (release_slot AND admit_slot both zero the
    slot's bandit rows)."""
    cfg, params = model
    spec = SpecConfig(k=4, w=3, strategy="mixed", max_new_tokens=10)
    eng = ServingEngine(params, cfg, spec, tables=tables, max_batch=1,
                        adaptive=True, arms=tuple(ARMS), buckets=(16,),
                        max_new_cap=10)
    a = eng.submit("first occupant", max_new_tokens=10)
    b = eng.submit("second occupant", max_new_tokens=10)
    done = {r.request_id: r for r in eng.serve_continuous()}
    pa, pb = done[a.request_id].stats["arm_pulls"], \
        done[b.request_id].stats["arm_pulls"]
    # same single slot served both; if stats leaked, b's pulls would
    # include a's and exceed its own call count
    assert sum(pa.values()) == done[a.request_id].stats["model_calls"]
    assert sum(pb.values()) == done[b.request_id].stats["model_calls"]
