"""xLSTM-125M: sLSTM + mLSTM blocks, no separate FFN sub-layer
[arXiv:2405.04517].  Period-4 pattern (3 mLSTM : 1 sLSTM ~ the paper's
mLSTM-heavy ratios)."""
import jax.numpy as jnp
from ..models.config import BlockSpec, ModelConfig

_PATTERN = (BlockSpec("mlstm", "none"), BlockSpec("mlstm", "none"),
            BlockSpec("mlstm", "none"), BlockSpec("slstm", "none"))


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", arch_type="ssm", source="arXiv:2405.04517",
        num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304,
        block_pattern=_PATTERN,
        norm="layernorm", rope="none",
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", arch_type="ssm", source="arXiv:2405.04517",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=512,
        block_pattern=(BlockSpec("mlstm", "none"), BlockSpec("slstm", "none")),
        norm="layernorm", rope="none",
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    ).validate()
