"""Finding/baseline/waiver plumbing shared by every repro-lint rule.

A *finding* is one violation of one rule, pinned to a location: a real
``file:line`` for AST rules, a pseudo-path like ``<case:linear-mixed/step>``
for jaxpr-level rules (which analyze traced programs, not source text).

Two suppression channels, with different lifetimes:

  - **inline waiver** — ``# repro-lint: allow(<rule>[,<rule>]): reason`` on
    the offending line (or the line directly above it).  For findings that
    are *accepted forever* at that exact site (e.g. the retirement path's
    necessary device->host readback).  Waived findings stay in the
    inventory (``--syncmap`` needs the full sync map, waived included) but
    never fail the build.
  - **baseline** — ``analysis/baseline.json``.  For *pre-existing* findings
    accepted at adoption time so CI can gate on NEW findings immediately.
    Entries match on (rule, file, context) — context is the stripped source
    line (AST) or a stable key (jaxpr), so findings survive line drift.
    The baseline is a ratchet: shrink it, never grow it.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

_WAIVER_RE = re.compile(
    r"#\s*repro-lint:\s*allow\(\s*(?P<rules>[\w, -]+?)\s*\)"
    r"(?::\s*(?P<reason>.*?))?\s*$")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str            # rule id, e.g. "donation" or "pallas-scope"
    file: str            # repo-relative path, or "<case:...>" pseudo-path
    line: int            # 1-based; 0 = whole entity (jaxpr-level)
    message: str         # what is wrong, concretely
    hint: str = ""       # how to fix it
    context: str = ""    # stable matching key (stripped source line / aval)
    waived: bool = False
    waive_reason: str = ""

    @property
    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.file, self.context)

    def format(self) -> str:
        loc = f"{self.file}:{self.line}" if self.line else self.file
        tag = " [waived]" if self.waived else ""
        s = f"{loc}: [{self.rule}]{tag} {self.message}"
        if self.hint:
            s += f"\n    fix: {self.hint}"
        return s

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def scan_waivers(source: str) -> Dict[int, Tuple[Set[str], str]]:
    """{line (1-based) -> (waived rule ids, reason)} for one source file.

    A waiver comment applies to its own line and, when the line holds only
    the comment, to the line below — so multi-line statements can carry the
    waiver above them.
    """
    out: Dict[int, Tuple[Set[str], str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _WAIVER_RE.search(text)
        if not m:
            continue
        rules = {r.strip() for r in m.group("rules").split(",") if r.strip()}
        reason = (m.group("reason") or "").strip()
        out[i] = (rules, reason)
        if text.lstrip().startswith("#"):       # comment-only line: applies
            out[i + 1] = (rules, reason)        # to the statement below
    return out


def apply_waivers(findings: Sequence[Finding],
                  waivers: Dict[int, Tuple[Set[str], str]]) -> List[Finding]:
    out = []
    for f in findings:
        w = waivers.get(f.line)
        if w and f.rule in w[0]:
            f = dataclasses.replace(f, waived=True, waive_reason=w[1])
        out.append(f)
    return out


class Baseline:
    """Accepted pre-existing findings (see module docstring)."""

    def __init__(self, entries: Optional[List[Dict]] = None):
        self.entries = entries or []
        self._keys = {(e["rule"], e["file"], e.get("context", ""))
                      for e in self.entries}

    @classmethod
    def load(cls, path: str) -> "Baseline":
        try:
            with open(path) as f:
                data = json.load(f)
        except FileNotFoundError:
            return cls([])
        return cls(data.get("entries", []))

    def covers(self, finding: Finding) -> bool:
        return finding.key in self._keys

    def split(self, findings: Sequence[Finding]
              ) -> Tuple[List[Finding], List[Finding]]:
        """(new, accepted) — waived findings count as accepted."""
        new, accepted = [], []
        for f in findings:
            (accepted if (f.waived or self.covers(f)) else new).append(f)
        return new, accepted
