"""End-to-end serving driver (deliverable b): train a small model for a few
hundred steps, then serve batched requests through the scheduler + engine,
comparing greedy vs the paper's mixed batched speculation — first with
static batching (serve_all), then with continuous batching (serve_continuous)
under staggered arrivals and heterogeneous max_new_tokens.

Run:  PYTHONPATH=src python examples/serve_speculative.py [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.spec_engine import SpecConfig
from repro.data.datasets import make_prompts
from repro.data.pipeline import mixed_batches
from repro.models.config import ModelConfig
from repro.serving import ServingEngine
from repro.train import AdamWConfig, init_train_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--requests", type=int, default=6)
args = ap.parse_args()

cfg = ModelConfig(name="serve-demo", num_layers=3, d_model=160, num_heads=4,
                  num_kv_heads=2, d_ff=384, vocab_size=259,
                  param_dtype=jnp.float32, compute_dtype=jnp.float32)
ts = init_train_state(jax.random.PRNGKey(0), cfg)
step = jax.jit(make_train_step(cfg, AdamWConfig(
    lr=1e-3, total_steps=args.steps, warmup_steps=args.steps // 10)))
t0 = time.time()
for i, b in enumerate(mixed_batches(8, 128, args.steps)):
    ts, m = step(ts, jnp.asarray(b))
print(f"trained {args.steps} steps in {time.time()-t0:.0f}s, "
      f"loss={float(m['loss']):.3f}")

prompts = [p for p, _ in make_prompts("code", args.requests)]
mixed_eng = None
for mode, spec in [("greedy", SpecConfig(strategy="greedy",
                                         max_new_tokens=48)),
                   ("spec(10,10)", SpecConfig(k=10, w=10, strategy="mixed",
                                              max_new_tokens=48))]:
    eng = ServingEngine(ts["params"], cfg, spec, max_batch=4)
    if spec.strategy == "mixed":
        mixed_eng = eng
    for p in prompts:
        eng.submit(p, max_new_tokens=48)
    t0 = time.time()
    reqs = eng.serve_all()
    dt = time.time() - t0
    tpc = sum(r.stats["tokens_per_call"] for r in reqs) / len(reqs)
    calls = sum(r.stats["model_calls"] for r in reqs)
    print(f"{mode:12s}: {len(reqs)} requests, {calls} total calls, "
          f"{tpc:.2f} tokens/call, wall {dt:.1f}s")
    print("   sample:", reqs[0].output[:70].replace("\n", "\\n"))

# --- continuous batching: staggered arrivals, heterogeneous budgets -------
# (the engine sizes its DecodeState from the queued prompts at first step)
cont_eng = ServingEngine(ts["params"], cfg,
                         SpecConfig(k=10, w=10, strategy="mixed"),
                         tables=mixed_eng.tables,  # reuse the one-off sweep
                         max_batch=4, max_new_cap=64)
for i, p in enumerate(prompts[: args.requests // 2]):
    cont_eng.submit(p, max_new_tokens=32 + 8 * (i % 3))
t0 = time.time()
done = []
for _ in range(3):                      # a few steps before the late wave
    done.extend(cont_eng.step())
for i, p in enumerate(prompts[args.requests // 2:]):
    cont_eng.submit(p, max_new_tokens=24 + 8 * (i % 3))
done.extend(cont_eng.serve_continuous())
dt = time.time() - t0
calls = sum(r.stats["model_calls"] for r in done)
toks = sum(r.stats["new_tokens"] for r in done)
print(f"{'continuous':12s}: {len(done)} requests, {calls} total calls, "
      f"{toks / max(calls, 1):.2f} tokens/call, wall {dt:.1f}s "
      f"(staggered arrivals, per-request budgets)")

# --- paged KV: same serving loop, slots share a page pool (DESIGN.md §8) --
paged_eng = ServingEngine(ts["params"], cfg,
                          SpecConfig(k=10, w=10, strategy="mixed"),
                          tables=mixed_eng.tables,
                          max_batch=4, max_new_cap=64, paged=True)
for p in prompts[: args.requests // 2]:
    paged_eng.submit(p, max_new_tokens=32)
done_p = paged_eng.serve_continuous()
toks_p = sum(r.stats["new_tokens"] for r in done_p)
print(f"{'paged':12s}: {len(done_p)} requests, {toks_p} tokens, "
      f"pool {paged_eng.pool_stats()}")
