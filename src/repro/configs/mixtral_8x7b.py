"""Mixtral-8x7B: 8 experts top-2, GQA kv=8, sliding-window attention
[arXiv:2401.04088]."""
import jax.numpy as jnp
from ..models.config import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", arch_type="moe", source="arXiv:2401.04088",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=32000,
        block_pattern=(BlockSpec("attn", "moe"),),
        num_experts=8, num_experts_per_tok=2,
        norm="rmsnorm", rope="rope", rope_theta=1e6,
        sliding_window=4096,
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", arch_type="moe", source="arXiv:2401.04088",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=512,
        block_pattern=(BlockSpec("attn", "moe"),),
        num_experts=4, num_experts_per_tok=2,
        norm="rmsnorm", rope="rope", rope_theta=1e6, sliding_window=64,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    ).validate()
