"""Transformer assembly: heterogeneous layer stacks as a compact scan.

Layers are grouped by position inside the repeating ``block_pattern`` and
their parameters stacked over the ``R = num_layers / period`` repetitions, so
the whole stack lowers to ONE ``lax.scan`` whose body contains one period
(Jamba: 8 sublayers, dense archs: 1).  This keeps the HLO small enough to
compile 96-layer/398B configs in the multi-pod dry-run.

Execution modes (static):
  "full"    — train / scoring: full self-attention, zero-init recurrent state.
  "prefill" — "full" + populate the decode state (KV buffers, final states).
  "decode"  — T new tokens from cached state, commit everything.
  "replay"  — decode with per-row gating ``n_commit``: only the first
              n_commit positions update caches/states (speculative commit of
              the winning row, see core/spec_engine.py).
  "verify"  — the paper's batched speculation: (B, k, w+1) rows attend to the
              shared cache bifurcated-ly; states are read-only; returns
              per-row logits (+ KV tails for attention-only fast commit).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import moe as moe_lib
from .attention import attn_full, attn_verify, init_attention
from .cache import (cache_buffer_len, group_ids, key_positions, kv_write,
                    paged_kv_write, prefill_write, select_step_state,
                    write_slots)
from .config import (ATTN, MAMBA, MLSTM, MOE, NO_MLP, SLSTM, BlockSpec,
                     ModelConfig)
from .layers import (apply_mlp, apply_norm, init_embed, init_mlp, init_norm)
from .mamba import init_mamba, mamba_mix, mamba_mix_steps
from .xlstm import (init_mlstm, init_slstm, mlstm_mix, slstm_mix)

Params = Dict[str, Any]


# ----------------------------------------------------------------------------
# init
# ----------------------------------------------------------------------------
def init_block(rng, cfg: ModelConfig, spec: BlockSpec) -> Params:
    ks = jax.random.split(rng, 2)
    p: Params = {"norm1": init_norm(cfg)}
    if spec.mixer == ATTN:
        p["mixer"] = init_attention(ks[0], cfg)
    elif spec.mixer == MAMBA:
        p["mixer"] = init_mamba(ks[0], cfg)
    elif spec.mixer == MLSTM:
        p["mixer"] = init_mlstm(ks[0], cfg)
    elif spec.mixer == SLSTM:
        p["mixer"] = init_slstm(ks[0], cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.mlp != NO_MLP:
        p["norm2"] = init_norm(cfg)
        if spec.mlp == MOE:
            p["mlp"] = moe_lib.init_moe(ks[1], cfg)
        else:
            p["mlp"] = init_mlp(ks[1], cfg, spec.mlp)
    return p


def init_params(rng, cfg: ModelConfig) -> Params:
    cfg.validate()
    groups = group_ids(cfg)
    ks = jax.random.split(rng, 2 + len(groups))
    params: Params = {"embed": init_embed(ks[0], cfg),
                      "final_norm": init_norm(cfg)}
    for (gid, spec, R), k in zip(groups, ks[2:]):
        keys = jax.random.split(k, R)
        params[gid] = jax.vmap(lambda kk: init_block(kk, cfg, spec))(keys)
    return params


# ----------------------------------------------------------------------------
# one sublayer in one mode
# ----------------------------------------------------------------------------
def _apply_block(bp: Params, x: jnp.ndarray, cfg: ModelConfig,
                 spec: BlockSpec, mode: str, gst: Optional[Dict],
                 ctx: Dict) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """Returns (x_out, new_group_state (or None), moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(bp["norm1"], x, cfg)
    new_gst: Optional[Dict] = None
    K = ctx.get("k_rows")  # static int for verify mode

    if spec.mixer == ATTN:
        if mode in ("full", "prefill"):
            y, (k_new, v_new) = attn_full(bp["mixer"], h, cfg,
                                          ctx["positions"])
            if mode == "prefill":
                if ctx.get("paged"):
                    # shared pool: positions routed through each slot's
                    # page table (model.prefill precomputed the physical
                    # slots; no ring semantics in the paged layout)
                    kc, vc = paged_kv_write(gst["k"], gst["v"], k_new,
                                            v_new, ctx["slots"])
                else:
                    kc, vc = prefill_write(cfg, gst["k"], gst["v"], k_new,
                                           v_new)
                new_gst = {"k": kc, "v": vc}
        elif mode in ("decode", "replay"):
            # Bifurcated decode (= verify with k=1): the query block attends
            # the shared cache and its own causal tail SEPARATELY, then the
            # new KV is scatter-written.  The previous concat([cache, new])
            # copied the full cache per layer AND forced an all-gather when
            # the operands' shardings disagreed — 2.1 TB/dev/token for
            # qwen2-72b decode_32k (EXPERIMENTS §Perf it-4).
            B, T = h.shape[:2]
            y, k_t, v_t = attn_verify(bp["mixer"], h[:, None], cfg,
                                      ctx["positions"], gst["k"], gst["v"],
                                      ctx["cache_pos"],
                                      cur_len=ctx.get("cur_len"),
                                      page_table=ctx.get("page_table"))
            y = y[:, 0]
            if ctx.get("paged"):
                kc, vc = paged_kv_write(gst["k"], gst["v"], k_t[:, 0],
                                        v_t[:, 0], ctx["slots"],
                                        gate=ctx.get("gate"))
            else:
                kc, vc = kv_write(gst["k"], gst["v"], k_t[:, 0], v_t[:, 0],
                                  ctx["slots"], gate=ctx.get("gate"))
            new_gst = {"k": kc, "v": vc}
        elif mode == "verify":
            B = h.shape[0] // K         # pool states carry no batch dim
            hv = h.reshape(B, K, h.shape[-2], h.shape[-1])
            y, k_t, v_t = attn_verify(bp["mixer"], hv, cfg, ctx["positions"],
                                      gst["k"], gst["v"], ctx["cache_pos"],
                                      cur_len=ctx.get("cur_len"),
                                      page_table=ctx.get("page_table"),
                                      tail_mask=ctx.get("tail_mask"))
            y = y.reshape(x.shape)
            new_gst = {"k_tail": k_t, "v_tail": v_t}
        else:
            raise ValueError(mode)

    elif spec.mixer == MAMBA:
        if mode in ("full", "prefill"):
            B = h.shape[0]
            conv0 = jnp.zeros((B, cfg.mamba_d_conv - 1, cfg.mamba_d_inner),
                              cfg.compute_dtype)
            ssm0 = jnp.zeros((B, cfg.mamba_d_inner, cfg.mamba_d_state),
                             jnp.float32)
            y, conv, ssm = mamba_mix(bp["mixer"], h, cfg, conv0, ssm0)
            if mode == "prefill":
                new_gst = {"conv": conv, "ssm": ssm}
        elif mode == "decode":
            y, conv, ssm = mamba_mix(bp["mixer"], h, cfg, gst["conv"],
                                     gst["ssm"])
            new_gst = {"conv": conv, "ssm": ssm}
        elif mode == "replay":
            y, conv_ext, ssm_steps = mamba_mix_steps(bp["mixer"], h, cfg,
                                                     gst["conv"], gst["ssm"])
            n = ctx["n_commit"]
            dc = cfg.mamba_d_conv
            # conv state after n steps = conv_ext[:, n : n+dc-1]
            conv = jax.vmap(
                lambda e, nn: jax.lax.dynamic_slice_in_dim(e, nn, dc - 1, 0)
            )(conv_ext, n)
            ssm = select_step_state(ssm_steps, gst["ssm"], n)
            new_gst = {"conv": conv.astype(gst["conv"].dtype), "ssm": ssm}
        elif mode == "verify":
            rep = lambda a: jnp.repeat(a, K, axis=0)
            y, _, _ = mamba_mix(bp["mixer"], h, cfg, rep(gst["conv"]),
                                rep(gst["ssm"]))
            new_gst = None

    elif spec.mixer == MLSTM:
        di = int(cfg.d_model * cfg.xlstm_mlstm_proj_factor)
        if mode in ("full", "prefill"):
            B = h.shape[0]
            nh = cfg.num_heads
            dh = di // nh
            st0 = (jnp.zeros((B, nh, dh, dh), jnp.float32),
                   jnp.zeros((B, nh, dh), jnp.float32),
                   jnp.full((B, nh), -1e9, jnp.float32))
            conv0 = jnp.zeros((B, cfg.xlstm_conv_kernel - 1, di),
                              cfg.compute_dtype)
            y, st, conv = mlstm_mix(bp["mixer"], h, cfg, st0, conv0,
                                    chunkwise=ctx.get("chunkwise", False))
            if mode == "prefill":
                new_gst = {"C": st[0], "n": st[1], "m": st[2], "conv": conv}
        elif mode == "decode":
            st = (gst["C"], gst["n"], gst["m"])
            y, st, conv = mlstm_mix(bp["mixer"], h, cfg, st, gst["conv"])
            new_gst = {"C": st[0], "n": st[1], "m": st[2], "conv": conv}
        elif mode == "replay":
            st = (gst["C"], gst["n"], gst["m"])
            y, st_steps, conv_ext = mlstm_mix(bp["mixer"], h, cfg, st,
                                              gst["conv"], per_step=True)
            n = ctx["n_commit"]
            dc = cfg.xlstm_conv_kernel
            conv = jax.vmap(
                lambda e, nn: jax.lax.dynamic_slice_in_dim(e, nn, dc - 1, 0)
            )(conv_ext, n)
            C, nv, m = select_step_state(
                st_steps, (gst["C"], gst["n"], gst["m"]), n)
            new_gst = {"C": C, "n": nv, "m": m,
                       "conv": conv.astype(gst["conv"].dtype)}
        elif mode == "verify":
            rep = lambda a: jnp.repeat(a, K, axis=0)
            st = (rep(gst["C"]), rep(gst["n"]), rep(gst["m"]))
            y, _, _ = mlstm_mix(bp["mixer"], h, cfg, st, rep(gst["conv"]))
            new_gst = None

    elif spec.mixer == SLSTM:
        if mode in ("full", "prefill"):
            B = h.shape[0]
            nh = cfg.num_heads
            dh = cfg.d_model // nh
            z = jnp.zeros((B, nh, dh), jnp.float32)
            st0 = (z, z, z, jnp.full((B, nh, dh), -1e9, jnp.float32))
            y, st = slstm_mix(bp["mixer"], h, cfg, st0)
            if mode == "prefill":
                new_gst = {"c": st[0], "n": st[1], "h": st[2], "m": st[3]}
        elif mode == "decode":
            st = (gst["c"], gst["n"], gst["h"], gst["m"])
            y, st = slstm_mix(bp["mixer"], h, cfg, st)
            new_gst = {"c": st[0], "n": st[1], "h": st[2], "m": st[3]}
        elif mode == "replay":
            st = (gst["c"], gst["n"], gst["h"], gst["m"])
            y, st_steps = slstm_mix(bp["mixer"], h, cfg, st, per_step=True)
            c, nv, hh, m = select_step_state(st_steps, st, ctx["n_commit"])
            new_gst = {"c": c, "n": nv, "h": hh, "m": m}
        elif mode == "verify":
            rep = lambda a: jnp.repeat(a, K, axis=0)
            st = (rep(gst["c"]), rep(gst["n"]), rep(gst["h"]), rep(gst["m"]))
            y, _ = slstm_mix(bp["mixer"], h, cfg, st)
            new_gst = None
    else:
        raise ValueError(spec.mixer)

    x = x + y.astype(x.dtype)

    if spec.mlp != NO_MLP:
        h2 = apply_norm(bp["norm2"], x, cfg)
        if spec.mlp == MOE:
            y2, aux = moe_lib.apply_moe(bp["mlp"], h2, cfg)
        else:
            y2 = apply_mlp(bp["mlp"], h2, cfg, spec.mlp)
        x = x + y2.astype(x.dtype)
    return x, new_gst, aux


# ----------------------------------------------------------------------------
# full stack
# ----------------------------------------------------------------------------
def run_stack(params: Params, cfg: ModelConfig, x: jnp.ndarray, mode: str,
              state: Optional[Dict], ctx: Dict,
              remat: bool = False) -> Tuple[jnp.ndarray, Dict, jnp.ndarray]:
    """Apply every layer. Returns (x, new_group_states, moe_aux_mean)."""
    aux0 = jnp.zeros((), jnp.float32)
    new_groups: Dict[str, Any] = {}

    # prefix layers (unrolled)
    for i, spec in enumerate(cfg.prefix_blocks):
        gid = f"pre{i}"
        bp = jax.tree_util.tree_map(lambda a: a[0], params[gid])
        gst = (jax.tree_util.tree_map(lambda a: a[0], state["groups"][gid])
               if state is not None and gid in state["groups"] else None)
        x, ngst, aux = _apply_block(bp, x, cfg, spec, mode, gst, ctx)
        aux0 = aux0 + aux
        if ngst is not None:
            new_groups[gid] = jax.tree_util.tree_map(lambda a: a[None], ngst)

    # periodic body: one scan over R periods
    P = cfg.pattern_period
    gids = [f"p{j}" for j in range(P)]
    xs_params = tuple(params[g] for g in gids)
    xs_state = None
    if state is not None:
        xs_state = tuple(state["groups"].get(g) for g in gids)

    from ..distributed import act_sharding

    def body(carry, xs):
        xc, aux = carry
        ps, sts = xs
        new_sts = []
        for j in range(P):
            gst = sts[j] if sts is not None else None
            xc, ngst, a = _apply_block(ps[j], xc, cfg, cfg.block_pattern[j],
                                       mode, gst, ctx)
            xc = act_sharding.constrain(xc, "residual")
            new_sts.append(ngst)
            aux = aux + a
        return (xc, aux), tuple(new_sts)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)

    from .runtime_flags import UNROLL_FOR_ANALYSIS
    if UNROLL_FOR_ANALYSIS:
        # python loop so HloCostAnalysis sees every layer (roofline calib)
        R = cfg.num_periods
        carry = (x, aux0)
        ys_list = []
        for r in range(R):
            xs_r = jax.tree_util.tree_map(lambda a: a[r],
                                          (xs_params, xs_state))
            carry, y_r = body(carry, xs_r)
            ys_list.append(y_r)
        x, aux_total = carry
        has_ys = len(jax.tree_util.tree_leaves(ys_list[0])) > 0
        ys = (jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys_list)
              if has_ys else ys_list[0])
    else:
        (x, aux_total), ys = jax.lax.scan(body, (x, aux0),
                                          (xs_params, xs_state))
    for gid, ngst in zip(gids, ys):
        if ngst is not None:
            new_groups[gid] = ngst
    n_moe = max(sum(1 for b in (tuple(cfg.prefix_blocks)
                                + tuple(cfg.block_pattern) * cfg.num_periods)
                    if b.mlp == MOE), 1)
    return x, new_groups, aux_total / n_moe
