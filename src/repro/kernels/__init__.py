"""Pallas TPU kernels for the paper's compute hot-spots (validated in
interpret mode on CPU; see tests/test_kernels_*).  Production code routes
through ``dispatch`` (backend selection + alignment), never ``ops`` directly.
"""
from . import dispatch, hashing, ngram_match, ops, ref, spec_attention  # noqa: F401
