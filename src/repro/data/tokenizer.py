"""Byte-level tokenizer (self-contained; no external vocab files).

IDs 0..255 are raw bytes; a handful of specials follow.  Models with larger
vocabularies simply have unused tail ids (harmless — logits over them are
learned to be improbable).
"""
from __future__ import annotations

from typing import Iterable, List

import numpy as np

PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
VOCAB_SIZE = 259


class ByteTokenizer:
    pad_id = PAD_ID
    bos_id = BOS_ID
    eos_id = EOS_ID
    vocab_size = VOCAB_SIZE

    def encode(self, text: str, bos: bool = True, eos: bool = False
               ) -> List[int]:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [BOS_ID] + ids
        if eos:
            ids = ids + [EOS_ID]
        return ids

    def decode(self, ids: Iterable[int]) -> str:
        b = bytes(i for i in ids if 0 <= i < 256)
        return b.decode("utf-8", errors="replace")

    def encode_batch(self, texts: List[str], length: int,
                     bos: bool = True) -> np.ndarray:
        out = np.full((len(texts), length), PAD_ID, np.int32)
        for i, t in enumerate(texts):
            ids = self.encode(t, bos=bos)[:length]
            out[i, :len(ids)] = ids
        return out
