"""DeepSeek-MoE-16B: fine-grained experts (64 routed top-6, width 1408) +
2 shared experts; dense first layer (d_ff=10944) [arXiv:2401.06066]."""
import jax.numpy as jnp
from ..models.config import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", arch_type="moe", source="arXiv:2401.06066",
        num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=10944, moe_d_ff=1408, vocab_size=102400,
        prefix_blocks=(BlockSpec("attn", "swiglu"),),
        block_pattern=(BlockSpec("attn", "moe"),),
        num_experts=64, num_experts_per_tok=6, num_shared_experts=2,
        norm="rmsnorm", rope="rope",
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke", arch_type="moe", source="arXiv:2401.06066",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, moe_d_ff=64, vocab_size=512,
        prefix_blocks=(BlockSpec("attn", "swiglu"),),
        block_pattern=(BlockSpec("attn", "moe"),),
        num_experts=4, num_experts_per_tok=2, num_shared_experts=1,
        norm="rmsnorm", rope="rope",
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    ).validate()
