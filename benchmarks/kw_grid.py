"""Figure 3 (+ App. A) reproduction: the (k, w) strategy grid.

tokens/call is MEASURED with the mixed strategy on the trained tiny model;
wall-time speedup is DERIVED for the paper-scale Mistral-7B on TPU v5e as
  speedup(k, w) = tokens_per_call(k, w) / slowdown(k, w | ell)
(core/phase.py roofline call-cost model; ell = mean decode context).
This is exactly the trade-off surface of the paper's Fig. 3: tokens/call
rises with (k, w) while the call gets slower once compute-bound.
"""
from __future__ import annotations

import csv
import os

from repro.configs import get_config
from repro.core.phase import slowdown
from repro.core.spec_engine import SpecConfig

from .common import TASKS, ensure_dirs, get_tables, get_trained, measure

KS = (1, 5, 10, 25)
WS = (2, 6, 10, 14)
FULL_KS = (1, 5, 10, 20, 25)
FULL_WS = (2, 4, 6, 8, 10, 12, 14)


def run(out_dir: str = "experiments/results", full: bool = False,
        max_new: int = 48) -> dict:
    ensure_dirs()
    cfg, params = get_trained()
    tables = get_tables(cfg, params)
    target = get_config("mistral-7b")     # speedup model target
    ks = FULL_KS if full else KS
    ws = FULL_WS if full else WS
    path = os.path.join(out_dir, "fig3_kw_grid.csv")
    best = {}
    with open(path, "w", newline="") as f:
        wr = csv.writer(f)
        wr.writerow(["task", "k", "w", "tokens_per_call",
                     "modeled_slowdown_v5e", "modeled_speedup_v5e",
                     "cpu_wall_s"])
        for task in TASKS:
            for k in ks:
                for w in ws:
                    spec = SpecConfig(k=k, w=w, strategy="mixed",
                                      max_new_tokens=max_new)
                    r = measure(cfg, params, tables, task, spec, n_prompts=4)
                    sl = slowdown(target, ell=512, k=k, w=w)
                    sp = r.tokens_per_call / sl
                    wr.writerow([task, k, w, f"{r.tokens_per_call:.3f}",
                                 f"{sl:.3f}", f"{sp:.3f}",
                                 f"{r.wall_s:.2f}"])
                    cur = best.get(task, (0.0, None))
                    if sp > cur[0]:
                        best[task] = (sp, (k, w), r.tokens_per_call)
    return {"csv": path, "best": best}


def main():
    res = run()
    print("fig3_kw_grid ->", res["csv"])
    for task, (sp, kw, tpc) in res["best"].items():
        print(f"  {task:5s}: best (k*,w*)={kw} tok/call={tpc:.2f} "
              f"modeled v5e speedup={sp:.2f}x")


if __name__ == "__main__":
    main()
