"""Mamba-1 selective SSM block (Jamba's sequence mixer).

TPU adaptation (see DESIGN.md §3): the CUDA hardware-aware scan becomes a
*chunked* scan — ``lax.scan`` over chunks of the sequence, with a parallel
``lax.associative_scan`` inside each chunk.  The (d_inner, d_state) state
never materialises for the full sequence, only per-chunk, which is what keeps
prefill_32k inside VMEM-sized working sets after sharding.

Decode/verify runs the same core over the (w+1)-token speculative block from
a cached (conv_state, ssm_state) — this is how the paper's batched
verification is adapted to SSMs (the state is snapshotted before the step and
recommitted for the winning row; see cache.py).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init

Params = Dict[str, jnp.ndarray]

MAMBA_CHUNK = 256


def init_mamba(rng, cfg: ModelConfig) -> Params:
    d, di, ds = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state
    dtr, dc = cfg.resolved_dt_rank, cfg.mamba_d_conv
    dt = cfg.param_dtype
    ks = jax.random.split(rng, 6)
    # S4D-real initialisation of A
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dt),
        "conv_w": dense_init(ks[1], (dc, di), dt, scale=1.0),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * ds), dt),
        "dt_proj": dense_init(ks[3], (dtr, di), dt),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32)
                             * (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001)),
                     min=1e-4))).astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], (di, d), dt),
    }


def _causal_conv_full(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                      state: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv. u: (B,T,di); w: (dc,di); state: (B,dc-1,di).

    Returns conv output (B,T,di) and the new state (last dc-1 inputs).
    """
    dc = w.shape[0]
    ext = jnp.concatenate([state.astype(u.dtype), u], axis=1)  # (B, T+dc-1, di)
    out = jnp.zeros_like(u)
    for i in range(dc):
        out = out + ext[:, i:i + u.shape[1], :] * w[i].astype(u.dtype)
    new_state = ext[:, -(dc - 1):, :] if dc > 1 else state
    return out + b.astype(u.dtype), new_state


def _ssm_chunk_body(A: jnp.ndarray, h: jnp.ndarray, u_c, dt_c, B_c, C_c):
    """One chunk of the selective scan.  All f32.

    h: (B, di, ds); u_c/dt_c: (B, c, di); B_c/C_c: (B, c, ds).
    """
    dA = jnp.exp(dt_c[..., None] * A)                       # (B,c,di,ds)
    dBx = (dt_c * u_c)[..., None] * B_c[:, :, None, :]      # (B,c,di,ds)

    def comb(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])

    cumA, hs = jax.lax.associative_scan(comb, (dA, dBx), axis=1)
    hs = hs + cumA * h[:, None]                              # fold carry in
    y = jnp.einsum("bcds,bcs->bcd", hs, C_c)
    return hs[:, -1], y


def selective_scan(u: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                   B: jnp.ndarray, C: jnp.ndarray, D: jnp.ndarray,
                   h0: jnp.ndarray, chunk: int = MAMBA_CHUNK
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """u/dt: (B,T,di) f32; B/C: (B,T,ds) f32; h0: (B,di,ds) f32.

    Returns (y (B,T,di), h_final).
    """
    from .runtime_flags import UNROLL_FOR_ANALYSIS
    Bt, T, di = u.shape
    if T <= chunk:
        h, y = _ssm_chunk_body(A, h0, u, dt, B, C)
        return y + u * D, h
    assert T % chunk == 0, f"T={T} not a multiple of chunk={chunk}"
    nc = T // chunk
    u_c = u.reshape(Bt, nc, chunk, di).swapaxes(0, 1)
    dt_c = dt.reshape(Bt, nc, chunk, di).swapaxes(0, 1)
    B_c = B.reshape(Bt, nc, chunk, -1).swapaxes(0, 1)
    C_c = C.reshape(Bt, nc, chunk, -1).swapaxes(0, 1)

    def body(h, xs):
        uc, dtc, bc, cc = xs
        h_new, y = _ssm_chunk_body(A, h, uc, dtc, bc, cc)
        return h_new, y

    if UNROLL_FOR_ANALYSIS:
        # python loop over chunks: exact HloCostAnalysis (roofline calib)
        h, ys = h0, []
        for i in range(nc):
            h, y_i = body(h, (u_c[i], dt_c[i], B_c[i], C_c[i]))
            ys.append(y_i)
        y = jnp.stack(ys).swapaxes(0, 1).reshape(Bt, T, di)
        return y + u * D, h

    h_final, ys = jax.lax.scan(body, h0, (u_c, dt_c, B_c, C_c))
    y = ys.swapaxes(0, 1).reshape(Bt, T, di)
    return y + u * D, h_final


def mamba_mix(params: Params, x: jnp.ndarray, cfg: ModelConfig,
              conv_state: jnp.ndarray, ssm_state: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full block: works for prefill (T large) and verify steps (T = w+1).

    conv_state: (B, dc-1, di); ssm_state: (B, di, ds) f32.
    Returns (y (B,T,d), new_conv_state, new_ssm_state).
    """
    cd = cfg.compute_dtype
    di, ds, dtr = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.resolved_dt_rank
    xz = x.astype(cd) @ params["in_proj"].astype(cd)
    u, z = jnp.split(xz, 2, axis=-1)
    u, new_conv = _causal_conv_full(u, params["conv_w"], params["conv_b"],
                                    conv_state)
    u = jax.nn.silu(u)
    proj = (u @ params["x_proj"].astype(cd)).astype(jnp.float32)
    dt_low, Bm, Cm = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt_low @ params["dt_proj"].astype(jnp.float32)
                         + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, h_new = selective_scan(u.astype(jnp.float32), dt, A, Bm, Cm,
                              params["D"], ssm_state)
    y = (y.astype(cd) * jax.nn.silu(z)) @ params["out_proj"].astype(cd)
    return y, new_conv, h_new


def mamba_mix_steps(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                    conv_state: jnp.ndarray, ssm_state: jnp.ndarray):
    """Like ``mamba_mix`` but returns per-step states (for speculative commit:
    the winner row's state after n accepted tokens is selected post hoc).

    T must be small (the w+1 speculative block).  Returns
    (y, conv_ext (B, T+dc-1, di), ssm_steps (B, T, di, ds)).
    State after t steps: conv = conv_ext[:, t:t+dc-1], ssm = ssm_steps[:, t-1].
    """
    cd = cfg.compute_dtype
    ds, dtr = cfg.mamba_d_state, cfg.resolved_dt_rank
    dc = cfg.mamba_d_conv
    xz = x.astype(cd) @ params["in_proj"].astype(cd)
    u, z = jnp.split(xz, 2, axis=-1)
    conv_ext = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)
    out = jnp.zeros_like(u)
    for i in range(dc):
        out = out + conv_ext[:, i:i + u.shape[1], :] * \
            params["conv_w"][i].astype(u.dtype)
    u = jax.nn.silu(out + params["conv_b"].astype(u.dtype))
    proj = (u @ params["x_proj"].astype(cd)).astype(jnp.float32)
    dt_low, Bm, Cm = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt_low @ params["dt_proj"].astype(jnp.float32)
                         + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    uf = u.astype(jnp.float32)
    dA = jnp.exp(dt[..., None] * A)
    dBx = (dt * uf)[..., None] * Bm[:, :, None, :]

    def comb(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])

    cumA, hs = jax.lax.associative_scan(comb, (dA, dBx), axis=1)
    hs = hs + cumA * ssm_state[:, None]
    y = jnp.einsum("bcds,bcs->bcd", hs, Cm) + uf * params["D"]
    y = (y.astype(cd) * jax.nn.silu(z)) @ params["out_proj"].astype(cd)
    return y, conv_ext, hs


def init_mamba_state(cfg: ModelConfig, batch: int) -> Tuple[jnp.ndarray,
                                                            jnp.ndarray]:
    conv = jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.mamba_d_inner),
                     cfg.compute_dtype)
    ssm = jnp.zeros((batch, cfg.mamba_d_inner, cfg.mamba_d_state), jnp.float32)
    return conv, ssm
