"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  - us_per_call: measured CPU microseconds per model call for the tiny
    benchmark model (this container has no TPU), where applicable;
  - derived: the headline derived metric of that experiment (tokens/call,
    modeled v5e speedup, slowdown, or dominant-term counts).
"""
from __future__ import annotations


def _row(name, us_per_call, derived):
    print(f"{name},{us_per_call},{derived}", flush=True)


def main() -> None:
    print("name,us_per_call,derived")

    from . import phase_transition
    res = phase_transition.run()
    _row("fig1_phase_transition", "n/a",
         f"slowdown(10;10|ell=500)={res['slowdown_10_10'][500]:.2f}x")

    from . import table1_speedup
    t1 = table1_speedup.run()
    for size, task, label, kw, tpc, sp, cpu_sp in t1["rows"]:
        if label == "best":
            _row(f"table1_{size}_{task}", "n/a",
                 f"tok/call={tpc:.2f};v5e_speedup={sp:.2f}x")

    from . import topk_curves
    t2 = topk_curves.run()
    best_b = max((v for (task, s, w, k), v in t2["results"].items()
                  if s == "bigram" and w == 2), default=0)
    _row("fig2_topk_curves", "n/a", f"bigram_w2_best_tok/call={best_b:.2f}")

    from . import kw_grid
    t3 = kw_grid.run()
    for task, (sp, kw, tpc) in t3["best"].items():
        _row(f"fig3_kw_grid_{task}", "n/a",
             f"(k*;w*)={kw[0]};{kw[1]};speedup={sp:.2f}x")

    from . import ablation_strategies
    t4 = ablation_strategies.run()
    for task, s in t4["summary"].items():
        _row(f"fig4_ablation_{task}", "n/a",
             f"mean_accept={s['mean_accept']:.2f}")

    from . import spec_call_bench
    t5 = spec_call_bench.run()
    for name, us, derived in t5["rows"]:
        _row(name, f"{us:.0f}", derived)

    t5b = spec_call_bench.run_backends()
    for backend, r in t5b["backends"].items():
        _row(f"backend_sweep_{backend}", f"{r['verify_call_us']:.0f}",
             f"tokens/s={r['tokens_per_s']:.1f};"
             f"tok/call={r['tokens_per_call']:.2f}")

    try:
        from . import roofline
        res = roofline.analyze()
        ok = [r for r in res.values() if r["status"] == "ok"]
        if ok:
            doms = {}
            for r in ok:
                doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
            _row("roofline_dryrun", "n/a",
                 f"cases={len(ok)};dominant=" + ";".join(
                     f"{k}:{v}" for k, v in sorted(doms.items())))
            import json
            import os
            os.makedirs("experiments/results", exist_ok=True)
            with open("experiments/results/roofline.md", "w") as f:
                f.write(roofline.to_markdown(res) + "\n")
            with open("experiments/results/roofline.json", "w") as f:
                json.dump(res, f, indent=1)
        else:
            _row("roofline_dryrun", "n/a", "no-dryrun-artifacts")
    except Exception as e:  # dry-run artifacts may not exist yet
        _row("roofline_dryrun", "n/a", f"unavailable:{type(e).__name__}")


if __name__ == "__main__":
    main()
