"""Draft strategies (paper §4): model-derived and context-derived N-grams.

Every drafter maps the current decode state to a fixed-shape batch of k
drafts of w tokens:  drafts (B, k, w) int32, valid (B, k) bool.  Invalid rows
are still verified (fixed shapes) but can never win more than the bonus
token, so correctness is unaffected — this is the fixed-shape TPU adaptation
of the paper's variable-length Python drafting.

The context N-gram uses a sort/hash reformulation of the paper's
``torch.unfold`` + ``torch.unique`` code (Appendix B.2), which is
jit-compatible and split into two stages:

  1. the O(L·(q+w)) *match/hash sweep* — compare the last q tokens against
     every context position and fingerprint every w-token continuation.
     This is the bandwidth-bound half and dispatches through
     ``kernels/dispatch.ngram_sweep`` to either the Pallas VPU kernel
     (``kernels/ngram_match.py``) or its XLA reference; both produce
     bit-identical integers (shared hash: ``kernels/hashing.py``).
  2. backend-independent *(count, recency) scoring + top-k* — occurrence
     counts via sorted-hash range queries, recency tie-break via a
     (count, position) lexicographic score, dedup by keeping the latest
     occurrence of each continuation.  Pure integer math on the sweep
     output, so drafts are provably identical under every backend.

Hash collisions are possible but *harmless*: a collision only merges the
counts of two different continuations; verification rejects any wrong token
(output equals greedy decoding bit-for-bit regardless).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..kernels import dispatch
from ..kernels.hashing import hash_rows as _hash_rows  # shared definition
from .ngram_tables import NGramTables


# ----------------------------------------------------------------------------
# model-derived drafters
# ----------------------------------------------------------------------------
def unigram_draft(tables: NGramTables, batch: int, k: int, w: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k unigram tokens, extended with bigram argmax chains (w > 1)."""
    first = tables.unigram_topk[:k]                       # (k,)
    drafts = _extend(tables, first[None].repeat(batch, 0), w)
    return drafts, jnp.ones((batch, k), bool)


def bigram_draft(tables: NGramTables, last_token: jnp.ndarray, k: int, w: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Extended model bigram (paper §4.1 'Extensions').

    last_token: (B,). Drafts row i = [topk_i(p(.|x)), argmax-chain...].
    """
    first = tables.bigram_topk[last_token][:, :k]         # (B, k)
    drafts = _extend(tables, first, w)
    return drafts, jnp.ones((first.shape[0], k), bool)


def _extend(tables: NGramTables, first: jnp.ndarray, w: int) -> jnp.ndarray:
    """first: (B, k) -> (B, k, w) via the precomputed argmax chain."""
    if w == 1:
        return first[..., None]
    tail = tables.bigram_chain[first][..., :w - 1]        # (B, k, w-1)
    return jnp.concatenate([first[..., None], tail], axis=-1)


# ----------------------------------------------------------------------------
# context-derived drafter
# ----------------------------------------------------------------------------
def _gram_matrix(buf: jnp.ndarray, width: int) -> jnp.ndarray:
    """buf: (L,) -> all windows (L - width + 1, width) (static shapes)."""
    L = buf.shape[0]
    return jnp.stack([buf[j:L - width + 1 + j] for j in range(width)], axis=-1)


def _extract_queries(buf: jnp.ndarray, cur_len: jnp.ndarray,
                     q: int) -> jnp.ndarray:
    """Last q committed tokens per row. buf: (B, L); cur_len: (B,) -> (B, q)."""
    slc = lambda b, c: jax.lax.dynamic_slice(
        b, (jnp.maximum(c - q, 0),), (q,))
    return jax.vmap(slc)(buf, cur_len)


def match_hash_sweep(buf: jnp.ndarray, cur_len: jnp.ndarray, q: int, w: int,
                     backend: str = "auto"
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Stage 1: the O(L·(q+w)) sweep, dispatched to Pallas or XLA.

    Returns (query (B,q), match (B,L) bool, hash (B,L) uint32); rows whose
    cur_len < q get a garbage query but are invalidated by the scoring
    stage's ``cur_len >= q+1`` guard.
    """
    query = _extract_queries(buf, cur_len, q)
    match, h = dispatch.ngram_sweep(buf.astype(jnp.int32), query,
                                    cur_len, w=w, backend=backend)
    return query, match.astype(bool), h


def _score_topk_row(bufp: jnp.ndarray, match: jnp.ndarray, h: jnp.ndarray,
                    cur_len: jnp.ndarray, q: int, k: int, w: int
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stage 2 (backend-independent): (count, recency) scoring + top-k.

    bufp: (L + q + w,) int32 padded buffer; match: (L,) bool; h: (L,) uint32;
    cur_len: () int32.  Pure integer math on the sweep output — identical
    drafts whichever backend produced (match, h).
    Returns (drafts (k, w), valid (k,)).
    """
    L = match.shape[0]
    idx = jnp.arange(L)
    match = match & (cur_len >= q + 1)
    SENTINEL = jnp.uint32(0xFFFFFFFF)
    hm = jnp.where(match, h, SENTINEL)
    hs = jnp.sort(hm)
    lo = jnp.searchsorted(hs, hm, side="left")
    hi = jnp.searchsorted(hs, hm, side="right")
    counts = (hi - lo)                                    # occurrences
    # dedup: keep only the LATEST matching position of each continuation
    # (recency also breaks count ties, per the paper): position j is
    # representative iff idx == max idx among its hash bucket, computed by
    # a forward running-max over equal-hash runs + a backward propagation.
    max_idx_sorted = jnp.where(match, idx, -1)
    order = jnp.argsort(hm)
    h_sorted = hm[order]
    i_sorted = max_idx_sorted[order]

    def scan_fn(carry, x):
        prev_h, prev_m = carry
        hh, ii = x
        m = jnp.where(hh == prev_h, jnp.maximum(prev_m, ii), ii)
        return (hh, m), m
    _, run_max = jax.lax.scan(scan_fn, (SENTINEL ^ 1, jnp.int32(-1)),
                              (h_sorted, i_sorted), reverse=False)

    # propagate run max backwards (max of run is at run end): reverse scan
    def scan_back(carry, x):
        prev_h, prev_m = carry
        hh, mm = x
        m = jnp.where(hh == prev_h, jnp.maximum(prev_m, mm), mm)
        return (hh, m), m
    _, bucket_max_sorted = jax.lax.scan(scan_back,
                                        (SENTINEL ^ 1, jnp.int32(-1)),
                                        (h_sorted, run_max), reverse=True)
    bucket_max = jnp.zeros((L,), jnp.int32).at[order].set(bucket_max_sorted)
    is_rep = match & (idx == bucket_max)
    # top-k by (count, recency), overflow-free: lexsort ascending by
    # (idx, count) with invalid rows pushed to the front, take the last k.
    cnt_key = jnp.where(is_rep, counts.astype(jnp.int32), -1)
    order2 = jnp.lexsort((idx, cnt_key))                  # ascending
    top_idx = order2[-k:][::-1]
    # gather the winning continuations: bufp[i+q : i+q+w] per winner
    drafts = jnp.stack([jnp.take(bufp, top_idx + q + j) for j in range(w)],
                       axis=-1)                           # (k, w)
    valid = cnt_key[top_idx] >= 0
    return drafts.astype(jnp.int32), valid


def context_ngram_draft(buf: jnp.ndarray, cur_len: jnp.ndarray, q: int,
                        k: int, w: int, backend: str = "auto"
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """buf: (B, L); cur_len: (B,). Returns (drafts (B,k,w), valid (B,k))."""
    B = buf.shape[0]
    _, match, h = match_hash_sweep(buf, cur_len, q, w, backend=backend)
    pad = jnp.full((B, q + w), -1, jnp.int32)
    bufp = jnp.concatenate([buf.astype(jnp.int32), pad], axis=1)
    score = lambda bp, m, hh, c: _score_topk_row(bp, m, hh, c, q, k, w)
    return jax.vmap(score)(bufp, match, h, cur_len.astype(jnp.int32))


# ----------------------------------------------------------------------------
# multi-depth drafting (adaptive arm masking, DESIGN.md §9)
# ----------------------------------------------------------------------------
def multi_depth_draft(draft_fn, ws: Tuple[int, ...], w_max: int,
                      widx: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Draft at every distinct masked depth and select per slot.

    ``draft_fn(w) -> (drafts (B,k,w), valid (B,k), n_ctx (B,))`` is invoked
    once per depth in ``ws`` (a static tuple, so every depth's sweep
    compiles into the SAME jitted step — per-slot arm switches can never
    trigger a recompile).  Each result is zero-padded to ``w_max`` and slot
    b takes the drafts of depth ``ws[widx[b]]``.

    Depth matters beyond truncation only for the context N-gram: its
    continuation hash and match guard are functions of w, so a depth-w_b
    draft inside a (k_max, w_max) step must come from a genuine depth-w_b
    sweep to be bit-identical to a dedicated (k, w_b) run.  The model-
    derived drafters are prefix-consistent in w (argmax chains), but are
    still routed through here so every strategy shares one parity story.
    Tokens past a slot's masked depth are zeros; they are never accepted
    (verify.accept gates on w_eff) and never committed.
    """
    ds, vs, ns = [], [], []
    for w in ws:
        d, v, n = draft_fn(w)
        ds.append(jnp.pad(d, ((0, 0), (0, 0), (0, w_max - w))))
        vs.append(v)
        ns.append(n)
    if len(ws) == 1:                       # single depth: nothing to select
        return ds[0], vs[0], ns[0]
    sel = widx[:, None, None, None]
    drafts = jnp.take_along_axis(jnp.stack(ds, axis=1), sel, axis=1)[:, 0]
    valid = jnp.take_along_axis(jnp.stack(vs, axis=1), sel[..., 0],
                                axis=1)[:, 0]
    n_ctx = jnp.take_along_axis(jnp.stack(ns, axis=1), widx[:, None],
                                axis=1)[:, 0]
    return drafts, valid, n_ctx


# ----------------------------------------------------------------------------
# mixed strategy (paper §4.3)
# ----------------------------------------------------------------------------
def mixed_draft(tables: NGramTables, buf: jnp.ndarray, cur_len: jnp.ndarray,
                last_token: jnp.ndarray, q: int, k: int, w: int,
                backend: str = "auto"
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Context N-gram matches first, extended model bigram fills the rest.

    Bigram fill rows are DEDUPLICATED against the context rows: a bigram
    candidate row identical to a committed context row would burn a verify
    row for zero acceptance gain (the earlier row wins every tie), so fill
    position r takes the r-th bigram candidate NOT duplicating a context
    row instead.  The skip decision for candidate j depends only on the
    context rows (the rows before every fill position) and never on k
    itself, and the m-th surviving candidate always has index
    <= m + n_ctx < k_b for the positions a (k_b <= k) arm keeps — so the
    dedup is prefix-consistent in k and the DESIGN.md §9 masked-arm parity
    contract is preserved (depth consistency comes from multi_depth_draft:
    rows are compared at the sweep's own w).  If duplicates outnumber the
    spare candidates the tail positions fall back to duplicate rows
    (harmless: fixed shapes require k rows).

    Returns (drafts (B,k,w), valid (B,k), n_context (B,) — allocation stat).
    """
    ctx_d, ctx_v = context_ngram_draft(buf, cur_len, q, k, w, backend=backend)
    big_d, _ = bigram_draft(tables, last_token, k, w)
    B = buf.shape[0]
    # compact the valid context drafts to the front, bigram after
    order = jnp.argsort(~ctx_v, axis=1, stable=True)       # valid first
    ctx_sorted = jnp.take_along_axis(ctx_d, order[..., None], axis=1)
    n_ctx = ctx_v.sum(axis=1)                              # (B,)
    row = jnp.arange(k)[None, :]
    use_ctx = row < n_ctx[:, None]
    # dup[b, j]: bigram candidate j token-identical to a context row in use
    dup = (big_d[:, :, None, :] == ctx_sorted[:, None, :, :]).all(axis=-1)
    dup = (dup & use_ctx[:, None, :]).any(axis=-1)         # (B, k)
    seq = jnp.argsort(dup, axis=1, stable=True)            # non-dups first,
    big_pos = jnp.clip(row - n_ctx[:, None], 0, k - 1)     # in index order
    big_idx = jnp.take_along_axis(seq, big_pos, axis=1)
    big_fill = jnp.take_along_axis(big_d, big_idx[..., None], axis=1)
    drafts = jnp.where(use_ctx[..., None], ctx_sorted, big_fill)
    valid = jnp.ones((B, k), bool)
    return drafts, valid, n_ctx.astype(jnp.int32)
