"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

# deliberately NOT marked slow: op-level interpret-mode checks run in
# seconds, and the CI backend-parity lane gates PRs on exactly this file


def _mk(seed, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,K,W1,H,KV,hd,S,bs",
    [(1, 1, 1, 1, 1, 16, 32, 16),     # degenerate: plain decode
     (2, 3, 4, 4, 2, 32, 64, 32),     # GQA
     (1, 5, 3, 8, 1, 64, 128, 64),    # MQA
     (2, 2, 6, 4, 4, 32, 96, 32),     # MHA, 3 blocks
     (1, 25, 4, 4, 2, 32, 64, 64)])   # paper-scale k
def test_spec_attention_sweep(B, K, W1, H, KV, hd, S, bs, dtype):
    q = _mk(0, (B, K, W1, H, hd), dtype)
    kc = _mk(1, (B, S, KV, hd), dtype)
    vc = _mk(2, (B, S, KV, hd), dtype)
    kt = _mk(3, (B, K, W1, KV, hd), dtype)
    vt = _mk(4, (B, K, W1, KV, hd), dtype)
    cur = jnp.asarray(np.random.default_rng(0).integers(0, S + 1, B),
                      jnp.int32)
    out = ops.spec_attention_op(q, kc, vc, kt, vt, cur, w1=W1, block_s=bs,
                                interpret=True)
    want = ops.spec_attention_ref_op(q, kc, vc, kt, vt, cur, w1=W1)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_spec_attention_empty_cache():
    """cur_len == 0: only the tail (incl. the leading token) attends."""
    B, K, W1, H, KV, hd, S = 1, 2, 3, 2, 1, 16, 32
    q = _mk(0, (B, K, W1, H, hd), jnp.float32)
    kc = jnp.zeros((B, S, KV, hd))
    vc = jnp.zeros((B, S, KV, hd))
    kt = _mk(1, (B, K, W1, KV, hd), jnp.float32)
    vt = _mk(2, (B, K, W1, KV, hd), jnp.float32)
    cur = jnp.zeros((B,), jnp.int32)
    out = ops.spec_attention_op(q, kc, vc, kt, vt, cur, w1=W1, block_s=32,
                                interpret=True)
    want = ops.spec_attention_ref_op(q, kc, vc, kt, vt, cur, w1=W1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("q,w,L,bl", [(1, 3, 64, 32), (2, 5, 128, 32),
                                      (3, 8, 256, 64), (1, 1, 32, 32)])
def test_ngram_match_sweep(q, w, L, bl):
    rng = np.random.default_rng(q * 100 + w)
    B = 2
    buf = jnp.asarray(rng.integers(0, 6, (B, L)), jnp.int32)
    qs = rng.integers(0, L - q)
    query = buf[:, qs:qs + q]
    cur = jnp.asarray(rng.integers(q, L + 1, B), jnp.int32)
    m, h = ops.ngram_match_op(buf, query, cur, w=w, block_l=bl,
                              interpret=True)
    bufp = jnp.concatenate([buf, jnp.full((B, q + w), -1, jnp.int32)], 1)
    m_r, h_r = jax.vmap(lambda b, qq, c: ref.ngram_match_ref(
        b, qq, c[None], w=w))(bufp, query, cur)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(m_r))
    np.testing.assert_array_equal(np.asarray(h), np.asarray(h_r))


def test_ngram_match_agrees_with_drafter_hash():
    """Kernel hash must equal the drafter's jnp hash (same constants)."""
    from repro.core.drafters import _gram_matrix, _hash_rows
    L, q, w = 64, 2, 3
    rng = np.random.default_rng(3)
    buf = jnp.asarray(rng.integers(0, 5, (1, L)), jnp.int32)
    query = buf[:, 10:12]
    cur = jnp.asarray([50], jnp.int32)
    m, h = ops.ngram_match_op(buf, query, cur, w=w, block_l=32,
                              interpret=True)
    grams = _gram_matrix(buf[0], q + w)
    h_drafter = _hash_rows(grams[:, q:])
    np.testing.assert_array_equal(np.asarray(h[0, :grams.shape[0]]),
                                  np.asarray(h_drafter))


@pytest.mark.parametrize("Bt,T,di,ds,chunk,bd",
                         [(2, 32, 16, 4, 8, 8), (1, 64, 32, 16, 16, 32),
                          (2, 16, 8, 2, 16, 8)])
def test_mamba_scan_kernel_sweep(Bt, T, di, ds, chunk, bd):
    ks = jax.random.split(jax.random.PRNGKey(T + di), 6)
    u = jax.random.normal(ks[0], (Bt, T, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, T, di)))
    A = -jnp.exp(jax.random.normal(ks[2], (di, ds)) * 0.3)
    B = jax.random.normal(ks[3], (Bt, T, ds))
    C = jax.random.normal(ks[4], (Bt, T, ds))
    D = jnp.ones((di,))
    h0 = jax.random.normal(ks[5], (Bt, di, ds))
    y_k, h_k = ops.mamba_scan_op(u, dt, A, B, C, D, h0, chunk=chunk,
                                 block_d=bd, interpret=True)
    y_r, h_r = ref.mamba_scan_ref(u, dt, A, B, C, D, h0)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=2e-4, atol=2e-4)


def test_mamba_scan_kernel_matches_model_layer():
    """Kernel output == the model's selective_scan (the production path)."""
    from repro.models.mamba import selective_scan
    Bt, T, di, ds = 1, 32, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    u = jax.random.normal(ks[0], (Bt, T, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, T, di)))
    A = -jnp.exp(jax.random.normal(ks[2], (di, ds)) * 0.3)
    B = jax.random.normal(ks[3], (Bt, T, ds))
    C = jax.random.normal(ks[4], (Bt, T, ds))
    D = jnp.ones((di,))
    h0 = jnp.zeros((Bt, di, ds))
    y_k, h_k = ops.mamba_scan_op(u, dt, A, B, C, D, h0, chunk=8,
                                 block_d=8, interpret=True)
    y_m, h_m = selective_scan(u, dt, A, B, C, D, h0, chunk=8)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m),
                               rtol=2e-4, atol=2e-4)
