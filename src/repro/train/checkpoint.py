"""Sharding-aware npz checkpointing (no orbax offline).

Pytrees are flattened with '/'-joined key paths; arrays are gathered to host
(fully addressable on the CPU container; on a real multi-host mesh each host
would save its addressable shards — the path-format is stable either way).
"""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(tree))


def load(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes preserved)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat_like, tdef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_k, leaf in flat_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
