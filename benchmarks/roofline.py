"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Reads experiments/dryrun/*.json produced by repro.launch.dryrun and reports,
per case:
    compute    = FLOPs_per_dev / peak_FLOPs            (197 TF/s bf16, v5e)
    memory     = bytes_per_dev / HBM_bw                (819 GB/s)
    collective = collective_bytes_per_dev / link_bw    (50 GB/s ICI)
plus the dominant term, MODEL_FLOPS = 6ND (train) / 2ND (inference, active
params for MoE), the useful-compute ratio, and a rule-generated suggestion.

Scan-undercount handling: XLA counts while-loop bodies once, so the dry-run
stores two UNROLLED reduced-depth calibration compiles (1 and 2 pattern
periods); we extrapolate linearly in depth:
    est(L_full) = cost(L1) + (L2-L1 periods)^-1 slope * (L_full - L1).
The sLSTM time recurrence stays scanned even unrolled (inherently
sequential) — its missing (T-1) body repeats are corrected analytically.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

from repro.configs import get_config
from repro.core.phase import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.launch.input_specs import SHAPES

def _extrapolate(calib: Dict, field_path, full_layers: int) -> Optional[float]:
    try:
        c1, c2 = calib["L1"], calib["L2"]
        v1, v2 = field_path(c1), field_path(c2)
        per_period = v2 - v1
        periods_full = (full_layers - calib["prefix_layers"]) \
            / calib["pattern_period"]
        return v1 + (periods_full - 1) * per_period
    except (KeyError, TypeError):
        return None


def _recurrent_correction_flops(cfg, shape_info, n_dev: int) -> float:
    """xLSTM cells stay `lax.scan`s even in calibration (inherently
    sequential / production-faithful), so HloCostAnalysis misses (T-1) body
    repeats per layer.  Analytic body flops:
      sLSTM: 4 gate matvecs against block-diag R -> ~2*4*nh*dh^2 / token
      mLSTM: C decay+outer-product+retrieval            -> ~8*nh*dh_m^2 / token
    """
    from repro.models.config import MLSTM, SLSTM, layer_blocks
    blocks = layer_blocks(cfg)
    n_slstm = sum(1 for b in blocks if b.mixer == SLSTM)
    n_mlstm = sum(1 for b in blocks if b.mixer == MLSTM)
    if n_slstm + n_mlstm == 0:
        return 0.0
    B = shape_info["batch"]
    T = shape_info["seq"] if shape_info["kind"] != "decode" else 1
    if T <= 1:
        return 0.0
    nh = cfg.num_heads
    dh_s = cfg.d_model // nh
    dh_m = int(cfg.d_model * cfg.xlstm_mlstm_proj_factor) // nh
    per_tok = (n_slstm * 8 * nh * dh_s * dh_s
               + n_mlstm * 8 * nh * dh_m * dh_m)
    return (T - 1) * B * per_tok / n_dev


def model_flops(cfg, shape_info, n_dev: int, spec_step: bool) -> float:
    """6ND (train) / 2ND (inference) with active params for MoE, per device."""
    n_active = cfg.param_count(active_only=True)
    B = shape_info["batch"]
    if shape_info["kind"] == "train":
        D = B * shape_info["seq"]
        return 6.0 * n_active * D / n_dev
    if shape_info["kind"] == "prefill":
        D = B * shape_info["seq"]
        return 2.0 * n_active * D / n_dev
    tokens = B * (110 if spec_step else 1)     # (k,w+1)=(10,11) spec rows
    return 2.0 * n_active * tokens / n_dev


def _suggest(dom: str, rec: dict) -> str:
    shape = rec["shape"]
    if dom == "collective":
        return ("reduce cross-device traffic: larger per-device shards "
                "(fewer FSDP all-gathers), overlap collectives with compute, "
                "or move the broken sharding (see counts) onto a divisible "
                "axis")
    if dom == "memory":
        if "decode" in shape or shape == "long_500k":
            return ("decode is KV/weight-bandwidth bound: batch more "
                    "requests per call or amortise weight reads over more "
                    "tokens — exactly what the paper's (k,w) batching does")
        return "increase arithmetic intensity: larger microbatch or fusion"
    return ("compute-bound: already near the MXU roof; only algorithmic "
            "savings (sparsity, distillation, fewer layers) help")


def analyze(dryrun_dir: str = "experiments/dryrun") -> Dict[str, dict]:
    out = {}
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        key = os.path.basename(path)[:-5]
        if rec.get("status") == "skip":
            out[key] = {"status": "skip", "reason": rec["skip_reason"],
                        "arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec.get("mesh")}
            continue
        if rec.get("status") != "ok":
            out[key] = {"status": "fail", "arch": rec.get("arch"),
                        "shape": rec.get("shape")}
            continue
        n_dev = rec["n_devices"]
        cfg = get_config(rec["arch"])
        shape_info = SHAPES[rec["shape"]]
        calib = rec.get("calib")
        flops = bytes_ = coll = None
        if calib:
            full = calib["full_layers"]
            flops = _extrapolate(calib, lambda c: c["cost"]["flops"], full)
            bytes_ = _extrapolate(calib,
                                  lambda c: c["cost"]["bytes accessed"],
                                  full)
            coll = _extrapolate(calib,
                                lambda c: c["collectives"]["total"], full)
        if flops is None:
            flops = rec["cost"].get("flops", 0.0)
        if bytes_ is None:
            bytes_ = rec["cost"].get("bytes accessed", 0.0)
        if coll is None:
            coll = float(rec["collectives"]["total"])
        flops += _recurrent_correction_flops(cfg, shape_info, n_dev)
        t_c = flops / PEAK_FLOPS
        t_m = bytes_ / HBM_BW
        t_x = coll / ICI_BW
        dom = max(("compute", t_c), ("memory", t_m),
                  ("collective", t_x), key=lambda kv: kv[1])[0]
        mf = model_flops(cfg, shape_info, n_dev, rec.get("spec_step", False))
        entry = {
            "status": "ok", "arch": rec["arch"], "shape": rec["shape"],
            "mesh": rec["mesh"], "spec_step": rec.get("spec_step", False),
            "flops_per_dev": flops, "bytes_per_dev": bytes_,
            "collective_bytes_per_dev": coll,
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "dominant": dom,
            "model_flops_per_dev": mf,
            "useful_ratio": mf / flops if flops else 0.0,
            "hbm_fit_16g": rec["memory"].get("total_hbm_bytes", 0) < 16 * 2**30,
            "hbm_gib": rec["memory"].get("total_hbm_bytes", 0) / 2**30,
            "suggestion": None,
        }
        entry["suggestion"] = _suggest(dom, rec)
        out[key] = entry
    return out


def to_markdown(results: Dict[str, dict]) -> str:
    lines = ["| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) | "
             "dominant | 6ND/HLO | HBM GiB/dev | fits |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for key, r in sorted(results.items()):
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','-')}"
                         f" | — | — | — | SKIP: {r['reason'][:40]} | — | — "
                         f"| — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | - | FAIL |||||||")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']}{'(spec)' if r['spec_step'] else ''} "
            f"| {r['mesh']} | {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
            f"| {r['t_collective_s']:.2e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['hbm_gib']:.1f} "
            f"| {'Y' if r['hbm_fit_16g'] else 'N'} |")
    return "\n".join(lines)


def main():
    res = analyze()
    os.makedirs("experiments/results", exist_ok=True)
    md = to_markdown(res)
    with open("experiments/results/roofline.md", "w") as f:
        f.write("# Roofline terms per (arch x shape x mesh)\n\n" + md + "\n")
    with open("experiments/results/roofline.json", "w") as f:
        json.dump(res, f, indent=1)
    print(md)
    n_ok = sum(1 for r in res.values() if r["status"] == "ok")
    print(f"\n{n_ok} analyzed -> experiments/results/roofline.md")


if __name__ == "__main__":
    main()
