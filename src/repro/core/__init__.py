"""The paper's primary contribution: learning-free batched speculation."""
from . import drafters, ngram_tables, phase, spec_engine, verify  # noqa: F401
from .ngram_tables import NGramTables, build_bigram, build_unigram  # noqa: F401
from .spec_engine import (DecodeState, PagedConfig, SpecConfig,  # noqa: F401
                          admit_slot, empty_decode_state, generate,
                          init_decode_state, release_slot, spec_step)
