"""Static draft-tree topology for tree-structured batched speculation.

Linear speculation (DESIGN.md §3) verifies k independent w-token rows and
commits the best one; every row re-drafts the FULL depth, so rows that agree
on a prefix burn verify positions re-scoring it, and a slot can only follow
ONE alternative per depth.  Tree speculation (SpecInfer/Medusa-style,
DESIGN.md §11) instead verifies a single token *tree* per slot: the first
``branch`` depths fan out over the drafter's top-``width`` candidates and
every leaf continues as an argmax chain, so shared prefixes are scored once
and the step can recover at any of the first ``branch`` depths where the
model's choice was only the drafter's 2nd..width-th guess.

Everything here is host-side numpy computed from STATIC ints
(width, depth, branch) — the topology folds into the jitted ``spec_step``
as compile-time constants (arrays below are baked into the trace), which is
what keeps tree arms inside the PR-4 zero-recompile masking contract.

Node/tuple convention: a node at depth ``l`` (1-based) is identified by its
branch tuple ``(b_1, .., b_l)`` with ``b_j < width`` for ``j <= branch`` and
``b_j == 0`` beyond; nodes are enumerated level-major, lexicographically
within a level, so the leaf paths come out in lexicographic tuple order.
Restricting to tuples with all entries ``< width_b`` preserves that order —
the masked-arm bit-parity argument (DESIGN.md §11) leans on exactly this.

The *verify inputs* are ``[root] + nodes``: input 0 is the last committed
token, input ``i+1`` is node ``i``; ``anc_mask[i, j]`` allows input i to
attend input j iff j is an ancestor-or-self of i, so each root-to-leaf path
behaves bit-identically to a linear draft row of the same tokens.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np


class TreeTopology(NamedTuple):
    """Static tree layout (all numpy; see module docstring for conventions)."""
    width: int
    depth: int
    branch: int
    parent: np.ndarray           # (N,) int32 parent node id, -1 = root
    level: np.ndarray            # (N,) int32 1-based depth of each node
    child: np.ndarray            # (N,) int32 branch-candidate index b_l
    spine: np.ndarray            # (N,) bool — tuple is (b_1, 0, .., 0)
    spine_row: np.ndarray        # (N,) int32 b_1 (the drafter row a spine tracks)
    sibling0: np.ndarray         # (N,) int32 node id of the parent's child 0
    path_nodes: np.ndarray       # (P, depth) int32 node ids along each leaf path
    path_inputs: np.ndarray      # (P, depth+1) int32 verify-input ids (root=0)
    path_max_branch: np.ndarray  # (P,) int32 max tuple entry (width masking)
    path_first: np.ndarray       # (P,) int32 b_1 of each path
    pos_off: np.ndarray          # (N+1,) int32 query-position offset per input
    anc_mask: np.ndarray         # (N+1, N+1) bool ancestor-or-self visibility

    @property
    def num_nodes(self) -> int:
        return int(self.parent.shape[0])

    @property
    def num_paths(self) -> int:
        return int(self.path_nodes.shape[0])


def effective_branch(depth: int, branch: int) -> int:
    return max(1, min(branch, depth)) if depth > 0 else 0


def num_nodes(width: int, depth: int, branch: int) -> int:
    """Node count of topology(width, depth, branch) without building it."""
    d = effective_branch(depth, branch)
    branched = sum(width ** j for j in range(1, d + 1))
    return branched + (width ** d) * (depth - d)


def num_paths(width: int, depth: int, branch: int) -> int:
    return width ** effective_branch(depth, branch) if depth > 0 else 0


@functools.lru_cache(maxsize=None)
def topology(width: int, depth: int, branch: int) -> TreeTopology:
    """Build the static topology for a (width, depth, branch) tree.

    Levels 1..min(branch, depth) fan out ``width`` children per node; deeper
    levels extend every leaf with a single chain child.  Cached: the same
    arrays are reused across traces of the same spec.
    """
    if width < 1 or depth < 1 or branch < 1:
        raise ValueError(
            f"tree needs width >= 1, depth >= 1, branch >= 1; got "
            f"({width}, {depth}, {branch})")
    d = effective_branch(depth, branch)
    parent, level, child, spine, spine_row, sibling0 = [], [], [], [], [], []
    node_of: dict = {}
    prev: list = [(-1, ())]                       # (node id, tuple) per leaf
    for lvl in range(1, depth + 1):
        wmax = width if lvl <= d else 1
        cur = []
        for pid, pt in prev:
            c0 = len(parent)                      # id the 0-child will get
            for b in range(wmax):
                nid = len(parent)
                t = pt + (b,)
                node_of[t] = nid
                parent.append(pid)
                level.append(lvl)
                child.append(b)
                spine.append(all(x == 0 for x in t[1:]))
                spine_row.append(t[0])
                sibling0.append(c0)
                cur.append((nid, t))
        prev = cur
    N = len(parent)
    P = len(prev)
    path_nodes = np.zeros((P, depth), np.int32)
    path_max_branch = np.zeros((P,), np.int32)
    path_first = np.zeros((P,), np.int32)
    for p, (nid, t) in enumerate(prev):
        n = nid
        for j in range(depth - 1, -1, -1):
            path_nodes[p, j] = n
            n = parent[n]
        path_max_branch[p] = max(t)
        path_first[p] = t[0]
    path_inputs = np.concatenate(
        [np.zeros((P, 1), np.int32), path_nodes + 1], axis=1)
    anc = np.zeros((N + 1, N + 1), bool)
    anc[0, 0] = True                              # root attends itself
    anc[1:, 0] = True                             # every node attends root
    for i in range(N):
        anc[i + 1, i + 1] = True
        a = parent[i]
        while a >= 0:
            anc[i + 1, a + 1] = True
            a = parent[a]
    return TreeTopology(
        width=width, depth=depth, branch=branch,
        parent=np.asarray(parent, np.int32),
        level=np.asarray(level, np.int32),
        child=np.asarray(child, np.int32),
        spine=np.asarray(spine, bool),
        spine_row=np.asarray(spine_row, np.int32),
        sibling0=np.asarray(sibling0, np.int32),
        path_nodes=path_nodes,
        path_inputs=path_inputs,
        path_max_branch=path_max_branch,
        path_first=path_first,
        pos_off=np.concatenate([np.zeros((1,), np.int32),
                                np.asarray(level, np.int32)]),
        anc_mask=anc)


def _context_next(buf: jnp.ndarray, buf_len: jnp.ndarray, gp: jnp.ndarray,
                  p: jnp.ndarray, fallback: jnp.ndarray) -> jnp.ndarray:
    """Buffer-local continuation of the (grandparent, parent) token pair.

    Finds the LATEST committed position j with ``buf[j] == gp`` and
    ``buf[j+1] == p`` whose continuation ``buf[j+2]`` is itself committed,
    and returns that continuation; rows with no such occurrence keep
    ``fallback`` (the global bigram argmax).  This is the order-2 flavour of
    the paper's context n-gram lookup re-seeded at a HYPOTHETICAL token —
    something only the tree layout can exploit (a linear row IS its seed).
    """
    S = buf.shape[1]
    pos = jnp.arange(S - 1, dtype=jnp.int32)
    m = (buf[:, :-1] == gp[:, None]) & (buf[:, 1:] == p[:, None])
    m &= (pos[None, :] + 2) < buf_len[:, None]
    j = jnp.max(jnp.where(m, pos[None, :], -1), axis=1)
    cont = jnp.take_along_axis(
        buf, jnp.clip(j + 2, 0, S - 1)[:, None], axis=1)[:, 0]
    return jnp.where(j >= 0, cont, fallback)


def fill_tree(topo: TreeTopology, drafts: jnp.ndarray, tables,
              buf: jnp.ndarray | None = None,
              buf_len: jnp.ndarray | None = None) -> jnp.ndarray:
    """Token content for every tree node: (B, k, w) linear drafts -> (B, N).

    Spine nodes (tuple (b, 0, .., 0)) replay drafter row b verbatim, so the
    tree's path set is a SUPERSET of the linear draft rows — tree acceptance
    at equal (width, depth) can only match or beat linear.  Off-spine
    children of node with token t take the model-bigram top candidates
    ``tables.bigram_topk[t]``; children of a *spine* parent additionally
    skip the candidate equal to the spine continuation (it is already the
    0-child), so a branch level never verifies the same token twice — the
    in-tree rendering of the mixed_draft dedup (DESIGN.md §11).

    When the committed token buffer is provided (``buf``/``buf_len``), the
    chain *tails* below a deviation are context-seeded: each chain child
    re-queries the buffer-local order-2 n-gram at its (grandparent, parent)
    hypothesis and copies what followed, falling back to the global bigram
    argmax when the pair never occurred.  A deviated branch thereby commits
    a workload-specific continuation in the SAME call that tested the
    branch — the lever behind the BENCH_tree seam wins — while branch
    levels keep the pure bigram top-k candidate lists (sibling sets stay
    duplicate-free).

    Dedicated-run parity (masked tree arms): every rule here depends only on
    the node's ancestors, a static candidate index and the shared committed
    buffer, never on ``width`` itself, so the nodes shared by a
    (width_b <= width) sub-tree carry identical tokens — see DESIGN.md §11
    for the full argument.

    Token correctness is NOT assumed anywhere: verification rejects any
    wrong token, so this only shapes tokens-per-call, never output content.
    """
    kmax = int(tables.bigram_topk.shape[1])
    if kmax < topo.width:
        raise ValueError(
            f"tree width {topo.width} needs bigram tables with k_max >= "
            f"width, got k_max={kmax}")
    big = tables.bigram_topk
    d = effective_branch(topo.depth, topo.branch)
    last = None
    if buf is not None:
        last = jnp.take_along_axis(
            buf, (buf_len - 1)[:, None], axis=1)[:, 0]
    toks = []
    for n in range(topo.num_nodes):
        lvl = int(topo.level[n])
        if bool(topo.spine[n]):
            t = drafts[:, int(topo.spine_row[n]), lvl - 1]
        else:
            pid = int(topo.parent[n])
            p_tok = toks[pid]
            cands = big[p_tok]                            # (B, k_max)
            c = int(topo.child[n])
            if buf is not None and lvl > d:
                # chain tail below a deviation: context-seed from the
                # committed buffer (grandparent of a level-2 node is the
                # root, i.e. the last committed token)
                gp = last if int(topo.level[pid]) == 1 else \
                    toks[int(topo.parent[pid])]
                t = _context_next(buf, buf_len, gp, p_tok, cands[:, 0])
            elif bool(topo.spine[int(topo.parent[n])]):
                # parent is on a spine: its 0-child is the drafter row's own
                # continuation; take candidate c-1, skipping over the
                # candidate that duplicates it (at most one — rows of
                # bigram_topk are distinct)
                s_tok = toks[int(topo.sibling0[n])]
                m = cands[:, :topo.width] == s_tok[:, None]
                j_dup = jnp.where(m.any(axis=1), jnp.argmax(m, axis=1),
                                  kmax + 1)
                base = jnp.full_like(j_dup, c - 1)
                idx = base + (j_dup <= base)
                t = jnp.take_along_axis(cands, idx[:, None], axis=1)[:, 0]
            else:
                # deviated parent: children are the candidate list directly
                # (0-child == argmax == the bigram chain continuation)
                t = cands[:, c]
        toks.append(t.astype(jnp.int32))
    return jnp.stack(toks, axis=1)                        # (B, N)


def arm_topologies(arms: Tuple[Tuple[int, int], ...], branch: int
                   ) -> Tuple[int, ...]:
    """Verify-node count per (width, depth) arm (0-depth arms verify only
    the root).  Used by the tree-aware roofline prior."""
    return tuple(num_nodes(k, w, branch) if w > 0 else 0 for k, w in arms)
