from . import attention, cache, config, layers, mamba, model, moe, transformer, xlstm  # noqa: F401
from .config import BlockSpec, ModelConfig  # noqa: F401
