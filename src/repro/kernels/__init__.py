"""Pallas TPU kernels for the paper's compute hot-spots (validated in
interpret mode on CPU; see tests/test_kernels_*)."""
from . import ngram_match, ops, ref, spec_attention  # noqa: F401
