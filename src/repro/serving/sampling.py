"""Sampling policies shared by the plain decode path and callers that want
one-off draws from a logits row.

Historical note: the paper's method verifies *greedy* continuations
(§Limitations defers non-greedy speculative sampling), and this module used
to declare the spec path greedy-only.  That limitation is closed: the
engine now serves temperature/top-p requests LOSSLESSLY through the same
jitted spec_step via rejection-verified speculative sampling
(core/verify.py, DESIGN.md §12) — submit with ``temperature > 0`` on
``ServingEngine.submit`` or pass ``--temperature`` to ``launch/serve.py``.
The helpers here are the plain (non-speculative) primitives; they shape
logits with the SAME ``core.verify.shape_logits`` the spec path uses, so
the two paths draw from identical distributions by construction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.verify import shape_logits


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(rng, logits: jnp.ndarray,
                       temperature: float = 1.0,
                       top_p: float = 1.0) -> jnp.ndarray:
    """Sample token ids from ``logits`` (..., V) at ``temperature`` with
    optional nucleus (top-p) truncation.

    ``temperature == 0`` is explicit greedy; NEGATIVE temperature raises —
    it is always a caller bug (e.g. a sign error in a schedule) and
    silently degrading it to greedy hid exactly that class of bug.  Logits
    are upcast to float32 before scaling and the categorical draw
    (shape_logits): dividing fp16/bf16 logits by a small temperature
    overflows half precision and quietly skews the distribution.
    """
    if temperature < 0.0:
        raise ValueError(
            f"temperature must be >= 0, got {temperature} (pass 0 for "
            f"greedy; a negative value is always a bug)")
    if temperature == 0.0:
        return greedy(logits)
    shaped = shape_logits(logits, temperature,
                          None if top_p >= 1.0 else top_p)
    return jax.random.categorical(rng, shaped, axis=-1).astype(jnp.int32)
