"""Logical-axis sharding rules with divisibility fallbacks.

Scheme (DESIGN.md §6): 2D ("data", "model") per pod, + leading "pod" axis
multi-pod.
  - "embed"-like param dims  -> FSDP over ("pod","data")  (what lets
    Nemotron-340B / Jamba-398B fit v5e HBM),
  - "heads"/"ffn"/"kv"/"vocab"/"expert" dims -> tensor/expert parallel over
    "model",
  - activation batch         -> ("pod", "data"),
  - KV-cache: kv-heads over "model" when divisible, else head_dim;
    batch over ("pod","data") when divisible, else cache sequence over
    "data" (the batch=1 long-context case).

Every rule degrades to replication when the dim isn't divisible by the mesh
axis — a sharding that fails to lower is a bug, a replicated small tensor is
not.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axes, in fallback order
_LOGICAL = {
    "embed": (("pod", "data"), ("data",)),
    "heads": (("model",),),
    "kv": (("model",),),
    "ffn": (("model",),),
    "vocab": (("model",),),
    "expert": (("model",),),
    None: (),
}


def _axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64))


def resolve_axis(mesh: Mesh, logical: Optional[str], dim: int):
    """Pick the first fallback whose size divides ``dim`` (else None)."""
    if logical is None:
        return None
    for axes in _LOGICAL[logical]:
        axes = tuple(a for a in axes if a in mesh.shape)
        if not axes:
            continue
        if dim % _axis_size(mesh, axes) == 0:
            return axes if len(axes) > 1 else axes[0]
    return None


def spec_for(mesh: Mesh, logicals: Tuple[Optional[str], ...],
             shape: Tuple[int, ...]) -> P:
    assert len(logicals) == len(shape), (logicals, shape)
    return P(*[resolve_axis(mesh, lg, d) for lg, d in zip(logicals, shape)])


# ----------------------------------------------------------------------------
# parameter rules, keyed by (parent, leaf-name)
# ----------------------------------------------------------------------------
_PARAM_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings
    "embedding": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    # norms
    "scale": (None,),
    "bias": (None,),
    # attention
    "wq": ("embed", "heads"),
    "wk": ("embed", "kv"),
    "wv": ("embed", "kv"),
    "wo": ("heads", "embed"),
    # dense mlps (and shared experts)
    "w_gate": ("embed", "ffn"),
    "w_up": ("embed", "ffn"),
    "w_down": ("ffn", "embed"),
    "shared_gate": ("embed", "ffn"),
    "shared_up": ("embed", "ffn"),
    "shared_down": ("ffn", "embed"),
    # moe (3D expert weights override the 2D mlp rules by rank below)
    "router": ("embed", None),
    # mamba
    "in_proj": ("embed", "ffn"),
    "conv_w": (None, "ffn"),
    "conv_b": ("ffn",),
    "x_proj": ("ffn", None),
    "dt_proj": (None, "ffn"),
    "dt_bias": ("ffn",),
    "A_log": ("ffn", None),
    "D": ("ffn",),
    "out_proj": ("ffn", "embed"),
    # mlstm
    "up_proj": ("embed", "ffn"),
    "w_if": (None, None),
    "b_i": (None,),
    "b_f": (None,),
    "gn_scale": (None,),
    "skip": (None,),
    "down_proj": ("ffn", "embed"),
    # slstm
    "w_in": ("embed", "ffn"),
    "r": (None, None, None, None),
    "b": (None,),
    "ffn_gate": ("embed", "ffn"),
    "ffn_up": ("embed", "ffn"),
    "ffn_down": ("ffn", "embed"),
}

_MOE_3D_RULES = {
    "w_gate": (("expert", "embed", None), (None, "embed", "ffn")),
    "w_up": (("expert", "embed", None), (None, "embed", "ffn")),
    "w_down": (("expert", None, "embed"), (None, "ffn", "embed")),
}


def _path_names(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_pspec(mesh: Mesh, path, leaf) -> P:
    names = _path_names(path)
    name = names[-1]
    shape = tuple(leaf.shape)
    # body/prefix groups are stacked over periods: leading None
    stacked = any(n.startswith("p") and n[1:].isdigit()
                  or n.startswith("pre") for n in names)
    core_shape = shape[1:] if stacked else shape
    if name in _MOE_3D_RULES and len(core_shape) == 3:
        for rule in _MOE_3D_RULES[name]:
            spec = [resolve_axis(mesh, lg, d)
                    for lg, d in zip(rule, core_shape)]
            if spec[0] is not None or rule[0] is None:
                break
        # fall through to the last rule if expert dim never divided
    elif name in _PARAM_RULES and len(_PARAM_RULES[name]) == len(core_shape):
        rule = _PARAM_RULES[name]
        spec = [resolve_axis(mesh, lg, d) for lg, d in zip(rule, core_shape)]
    else:
        spec = [None] * len(core_shape)
    if stacked:
        spec = [None] + spec
    return P(*spec)


def params_shardings(mesh: Mesh, params_shapes) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_pspec(mesh, path, leaf)),
        params_shapes)


# ----------------------------------------------------------------------------
# decode-state rules
# ----------------------------------------------------------------------------
def _batch_axes(mesh: Mesh, b: int):
    return resolve_axis(mesh, "embed", b)   # ("pod","data") fallback chain


def state_pspec(mesh: Mesh, path, leaf) -> P:
    names = _path_names(path)
    name = names[-1]
    shape = tuple(leaf.shape)
    if name == "cur_len":
        return P(None)
    R, B = shape[0], shape[1]
    batch = _batch_axes(mesh, B)
    if name in ("k", "v"):                      # (R, B, S, KV, hd)
        _, _, S, KV, hd = shape
        kv_ax = resolve_axis(mesh, "kv", KV)
        seq_ax = None
        if kv_ax is None and S % mesh.shape.get("model", 1) == 0:
            # kv heads don't divide the model axis (kv=8/2/1 GQA): shard the
            # cache SEQUENCE over "model" instead — attention contracts hd
            # (replicated) and softmaxes over the sharded seq with small
            # partial-reduce collectives.  Sharding hd instead forces an
            # all-reduce of full (.., S) logits per layer (§Perf it-5).
            seq_ax = "model"
        if batch is None and seq_ax is None:
            # batch=1 long-context: shard the cache sequence over "data"
            seq_ax = "data" if S % mesh.shape.get("data", 1) == 0 else None
        return P(None, batch, seq_ax, kv_ax, None)
    if name == "conv":                          # (R, B, dc-1, di)
        return P(None, batch, None, resolve_axis(mesh, "ffn", shape[-1]))
    if name == "ssm":                           # (R, B, di, ds)
        return P(None, batch, resolve_axis(mesh, "ffn", shape[2]), None)
    if name == "C":                             # (R, B, nh, dh, dh)
        nh_ax = resolve_axis(mesh, "heads", shape[2])
        dh_ax = resolve_axis(mesh, "heads", shape[3]) if nh_ax is None \
            else None
        return P(None, batch, nh_ax, dh_ax, None)
    if name in ("n", "h", "c", "m"):            # (R,B,nh[,dh])
        nh_ax = resolve_axis(mesh, "heads", shape[2])
        rest = [None] * (len(shape) - 3)
        if nh_ax is None and len(shape) > 3:
            rest[0] = resolve_axis(mesh, "heads", shape[3])
        return P(None, batch, nh_ax, *rest)
    return P(*([None] * len(shape)))


def state_shardings(mesh: Mesh, state_shapes) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, state_pspec(mesh, path, leaf)),
        state_shapes)


def batch_sharding(mesh: Mesh, shape: Tuple[int, ...],
                   batch_dim: int = 0) -> NamedSharding:
    """Tokens / embeds / logits: batch over ("pod","data"), rest replicated.

    Exception: (3, B, T) M-RoPE positions -> batch_dim=1.
    """
    spec = [None] * len(shape)
    spec[batch_dim] = _batch_axes(mesh, shape[batch_dim])
    return NamedSharding(mesh, P(*spec))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
