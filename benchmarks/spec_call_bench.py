"""Model-call microbenchmark (the engine-level analogue of the paper's
CUDA-event timings): CPU wall time per call for decode (1,1) vs verification
(k, w+1), plus the drafter cost — demonstrating 'negligible-cost' drafting
(P1/P2): the drafter must be orders of magnitude cheaper than a model call.

``run_backends`` additionally sweeps the kernel-dispatch backend
(xla | pallas) through the same verify call and a short end-to-end
``generate``, writing ``BENCH_backends.json`` (repo root) so the perf
trajectory of the Pallas fast path is recorded from day one.  On this CPU
container pallas numbers are interpret-mode (correctness signal, not speed);
on a TPU the same sweep measures the real kernels.
"""
from __future__ import annotations

import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.core.drafters import mixed_draft
from repro.core.spec_engine import SpecConfig, generate
from repro.kernels import dispatch
from repro.models import model as M

from .common import ensure_dirs, get_tables, get_trained, task_prompts


def _time(fn, *args, n=20):
    out = fn(*args)
    jax.tree_util.tree_leaves(out)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.tree_util.tree_leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6  # us


def run(max_len: int = 256) -> dict:
    ensure_dirs()
    cfg, params = get_trained()
    tables = get_tables(cfg, params)
    B, P = 4, 64
    toks = jax.random.randint(jax.random.PRNGKey(0), (B, P), 0,
                              cfg.vocab_size)
    state = M.init_state(cfg, B, max_len)
    _, state = jax.jit(lambda s, t: M.prefill(params, cfg, s, tokens=t)
                       )(state, toks)
    rows = []

    dec = jax.jit(lambda s, t: M.decode(params, cfg, s, t))
    us_dec = _time(lambda: dec(state, toks[:, :1]))
    rows.append(("call_decode_1x1", us_dec, "baseline"))

    for (k, w) in [(5, 4), (10, 10), (25, 14)]:
        vt = jax.random.randint(jax.random.PRNGKey(1), (B, k, w + 1), 0,
                                cfg.vocab_size)
        ver = jax.jit(lambda s, r: M.verify(params, cfg, s, r))
        us_v = _time(lambda: ver(state, vt))
        rows.append((f"call_verify_k{k}_w{w}", us_v,
                     f"slowdown_vs_decode={us_v/us_dec:.2f}x"))

    buf = jnp.zeros((B, max_len), jnp.int32
                    ).at[:, :P].set(toks)
    cur = jnp.full((B,), P, jnp.int32)
    drafter = jax.jit(lambda b, c, l: mixed_draft(tables, b, c, l, 1, 10, 10))
    us_d = _time(lambda: drafter(buf, cur, toks[:, -1]))
    rows.append(("drafter_mixed_k10_w10", us_d,
                 f"fraction_of_decode_call={us_d/us_dec:.3f}"))
    return {"rows": rows}


def run_backends(max_len: int = 192, gen_tokens: int = 24,
                 k: int = 10, w: int = 4) -> dict:
    """Backend sweep: per-verify-call latency + end-to-end tokens/s under
    ``backend="xla"`` vs ``backend="pallas"``.  Writes BENCH_backends.json.
    """
    ensure_dirs()
    cfg0, params = get_trained()
    tables = get_tables(cfg0, params)
    B, P = 4, 64
    toks = jax.random.randint(jax.random.PRNGKey(0), (B, P), 0,
                              cfg0.vocab_size)
    vt = jax.random.randint(jax.random.PRNGKey(1), (B, k, w + 1), 0,
                            cfg0.vocab_size)
    prompts = task_prompts("chat", B, P)
    res = {"interpret": dispatch.default_interpret(),
           "k": k, "w": w, "gen_tokens": gen_tokens, "backends": {}}
    for backend in ("xla", "pallas"):
        cfg = dataclasses.replace(cfg0, backend=backend).validate()
        state = M.init_state(cfg, B, max_len)
        _, state = jax.jit(lambda s, t: M.prefill(params, cfg, s, tokens=t)
                           )(state, toks)
        ver = jax.jit(lambda s, r: M.verify(params, cfg, s, r))
        # interpret-mode pallas is orders slower on CPU; fewer reps suffice
        reps = 20 if backend == "xla" else 3
        us_v = _time(lambda: ver(state, vt), n=reps)
        spec = SpecConfig(k=k, w=w, strategy="mixed",
                          max_new_tokens=gen_tokens, backend=backend)
        gen = jax.jit(lambda p, t, tbl: generate(p, cfg, spec, t, tbl))
        buf, _, stats = gen(params, prompts, tables)     # compile
        buf.block_until_ready()
        t0 = time.perf_counter()
        buf, _, stats = gen(params, prompts, tables)
        buf.block_until_ready()
        wall = time.perf_counter() - t0
        tokens = int(jnp.sum(stats["tokens"]))
        calls = int(jnp.sum(stats["calls"]))
        res["backends"][backend] = {
            "verify_call_us": us_v,
            "tokens_per_s": tokens / wall,
            "tokens_per_call": tokens / max(calls, 1),
            "generate_wall_s": wall,
        }
    with open("BENCH_backends.json", "w") as f:
        json.dump(res, f, indent=1)
    return res


def main():
    for name, us, derived in run()["rows"]:
        print(f"{name:24s} {us:10.0f} us   {derived}")
    res = run_backends()
    for backend, r in res["backends"].items():
        print(f"backend_{backend:7s} verify={r['verify_call_us']:10.0f} us  "
              f"tokens/s={r['tokens_per_s']:8.1f}  "
              f"tok/call={r['tokens_per_call']:.2f}")
    print("wrote BENCH_backends.json"
          + (" (pallas in interpret mode)" if res["interpret"] else ""))


if __name__ == "__main__":
    main()
