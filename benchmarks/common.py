"""Shared benchmark infrastructure.

Trains (once, cached to experiments/models/) a tiny byte-level model per
task family, builds its learning-free tables, and provides tokens/call
measurement — the paper's primary metric.  Wall-time *speedups* for the
paper-scale models are derived from the TPU-v5e roofline call-cost model
(core/phase.py), since this container has no accelerator; CPU wall-time is
also reported for the tiny models as a sanity signal.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ngram_tables import NGramTables, build_bigram, build_unigram
from repro.core.spec_engine import SpecConfig, generate
from repro.data.datasets import make_prompts
from repro.data.pipeline import packed_batches
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.train import AdamWConfig, init_train_state, make_train_step
from repro.train.checkpoint import load, save

MODEL_DIR = "experiments/models"
TASKS = ("code", "math", "chat")

# Tiny stand-ins for the paper's {Phi3B, Mistral7B, Vicuna13B} lineup: same
# experiment structure, CPU-trainable scale.
SIZES = {
    "tiny-31m": dict(num_layers=2, d_model=128, d_ff=256),
    "tiny-59m": dict(num_layers=3, d_model=160, d_ff=384),
}
DEFAULT_SIZE = "tiny-31m"


def bench_config(size: str = DEFAULT_SIZE) -> ModelConfig:
    kw = SIZES[size]
    return ModelConfig(name=f"bench-{size}", num_heads=4, num_kv_heads=2,
                       vocab_size=259, param_dtype=jnp.float32,
                       compute_dtype=jnp.float32, **kw).validate()


def get_trained(size: str = DEFAULT_SIZE, steps: int = 120,
                seed: int = 0) -> Tuple[ModelConfig, Dict]:
    """Train (or load cached) the benchmark model on the 3-task mixture."""
    cfg = bench_config(size)
    path = os.path.join(MODEL_DIR, f"{cfg.name}.npz")
    ts = init_train_state(jax.random.PRNGKey(seed), cfg)
    if os.path.exists(path):
        return cfg, load(path, ts["params"])
    from repro.data.pipeline import mixed_batches
    step = jax.jit(make_train_step(cfg, AdamWConfig(
        lr=1e-3, total_steps=steps, warmup_steps=10)))
    for b in mixed_batches(8, 128, steps, seed=seed):
        ts, metrics = step(ts, jnp.asarray(b))
    save(path, ts["params"])
    print(f"  trained {cfg.name}: loss={float(metrics['loss']):.3f}")
    return cfg, ts["params"]


_TABLE_CACHE: Dict[str, NGramTables] = {}


def get_tables(cfg: ModelConfig, params, k_max: int = 32,
               w_max: int = 16) -> NGramTables:
    key = f"{cfg.name}-{k_max}-{w_max}"
    if key not in _TABLE_CACHE:
        fwd = jax.jit(lambda t: M.forward(params, cfg, tokens=t)[0][:, -1])
        topk, chain = build_bigram(fwd, cfg.vocab_size, k_max=k_max,
                                   w_max=w_max, batch=259)
        uni = build_unigram(params["embed"]["embedding"],
                            params["embed"]["lm_head"], k_max=k_max)
        _TABLE_CACHE[key] = NGramTables(uni, topk, chain)
    return _TABLE_CACHE[key]


def task_prompts(task: str, n: int, prompt_len: int = 48) -> jnp.ndarray:
    tok = ByteTokenizer()
    texts = [p for p, _ in make_prompts(task, n, seed=1)]
    return jnp.asarray(tok.encode_batch(texts, prompt_len))


@dataclasses.dataclass
class RunResult:
    tokens_per_call: float
    new_tokens: int
    calls: int
    wall_s: float
    stats: Dict[str, np.ndarray]


def measure(cfg, params, tables, task: str, spec: SpecConfig,
            n_prompts: int = 8, prompt_len: int = 48) -> RunResult:
    prompts = task_prompts(task, n_prompts, prompt_len)
    fn = jax.jit(lambda p, t, tbl: generate(p, cfg, spec, t, tbl))
    buf, blen, stats = fn(params, prompts, tables)   # compile
    buf.block_until_ready()
    t0 = time.perf_counter()
    buf, blen, stats = fn(params, prompts, tables)
    buf.block_until_ready()
    wall = time.perf_counter() - t0
    stats = {k: np.asarray(v) for k, v in stats.items()}
    calls = int(stats["calls"].sum())
    tokens = int(stats["tokens"].sum())
    return RunResult(tokens_per_call=tokens / max(calls, 1),
                     new_tokens=tokens, calls=calls, wall_s=wall,
                     stats=stats)


def ensure_dirs():
    os.makedirs(MODEL_DIR, exist_ok=True)
    os.makedirs("experiments/results", exist_ok=True)
