"""Top-level language-model API: init / forward / prefill / decode / verify.

These are the pure functions the training loop, the serving engine and the
speculative-decoding core compose.  Everything is jit-friendly: shapes are
static, sequence advance is tracked by ``state["cur_len"]``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .cache import (group_ids, init_state, is_paged, key_positions, kv_write,
                    paged_dims, paged_kv_write, phys_slots, write_slots)
from .config import ATTN, MROPE, ModelConfig, layer_blocks
from .layers import apply_norm, embed_tokens, lm_logits
from .transformer import init_params, run_stack

Params = Dict[str, Any]
State = Dict[str, Any]

__all__ = ["init_params", "init_state", "forward", "prefill", "decode",
           "verify", "commit_kv_tails", "has_recurrent", "make_positions"]


def has_recurrent(cfg: ModelConfig) -> bool:
    return any(b.mixer != ATTN for b in layer_blocks(cfg))


def make_positions(cfg: ModelConfig, B: int, T: int,
                   offset: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    if offset is not None:
        pos = pos + offset[:, None]
    if cfg.rope == MROPE:
        # text tokens: t/h/w positions coincide (Qwen2-VL §3.1)
        pos = jnp.broadcast_to(pos[None], (3, B, T))
    return pos


def _embed(params: Params, cfg: ModelConfig, tokens, embeds):
    if embeds is not None:
        return embeds.astype(cfg.compute_dtype)
    return embed_tokens(params["embed"], tokens, cfg)


def forward_hidden(params: Params, cfg: ModelConfig, tokens=None,
                   embeds=None, positions=None, remat: bool = False
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full forward up to the final norm. Returns (hidden (B,T,d), moe_aux).

    Splitting the LM head out lets the training loss compute logits in
    vocab/time chunks (train_loop.chunked_lm_loss) — materialising the full
    (B, T, 256k) f32 logits of Nemotron/Gemma-class vocabs would not fit
    v5e HBM.
    """
    x = _embed(params, cfg, tokens, embeds)
    B, T = x.shape[:2]
    if positions is None:
        positions = make_positions(cfg, B, T)
    ctx = {"positions": positions}
    x, _, aux = run_stack(params, cfg, x, "full", None, ctx, remat=remat)
    return apply_norm(params["final_norm"], x, cfg), aux


def forward(params: Params, cfg: ModelConfig, tokens=None, embeds=None,
            positions=None, remat: bool = False
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full forward (train / scoring). Returns (logits f32, moe_aux)."""
    x, aux = forward_hidden(params, cfg, tokens, embeds, positions, remat)
    return lm_logits(params["embed"], x, cfg), aux


def prefill(params: Params, cfg: ModelConfig, state: State, tokens=None,
            embeds=None, positions=None,
            last_only: bool = False) -> Tuple[jnp.ndarray, State]:
    """Process the prompt, populating ``state``. All rows same length T.

    ``state`` must be freshly allocated (cur_len == 0).  ``last_only``
    computes logits for the final position only (serving never needs the
    rest; a 32k x 152k-vocab logit tensor would dwarf the KV cache).
    """
    x = _embed(params, cfg, tokens, embeds)
    B, T = x.shape[:2]
    if positions is None:
        positions = make_positions(cfg, B, T)
    ctx = {"positions": positions}
    if is_paged(state):
        # prefill writes positions 0..T-1 of every row through its page
        # table (pages must already be allocated — see spec_engine)
        NP, ps, _ = paged_dims(state)
        pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        ctx["paged"] = True
        ctx["slots"] = phys_slots(state["page_table"], pos, ps, NP)
    x, new_groups, _ = run_stack(params, cfg, x, "prefill", state, ctx)
    x = apply_norm(params["final_norm"], x, cfg)
    if last_only:
        x = x[:, -1:]
    logits = lm_logits(params["embed"], x, cfg)
    new_state = {**state, "cur_len": state["cur_len"] + T,
                 "groups": {**state["groups"], **new_groups}}
    return logits, new_state


def decode(params: Params, cfg: ModelConfig, state: State,
           tokens: jnp.ndarray,
           n_commit: Optional[jnp.ndarray] = None
           ) -> Tuple[jnp.ndarray, State]:
    """Decode T new tokens from cached state.

    With ``n_commit`` (B,), runs in *replay* mode: only the first n_commit
    positions of each row update the caches/recurrent state — this is the
    speculative commit of the winning draft (paper App. D's "overwrite all
    rows with the accepted speculation", adapted to recurrent state).
    """
    B, T = tokens.shape[:2]
    cur = state["cur_len"]
    positions = make_positions(cfg, B, T, offset=cur)
    gid0 = next(gid for gid, s, _ in group_ids(cfg) if s.mixer == ATTN
                ) if not _pure_recurrent(cfg) else None
    adv = n_commit if n_commit is not None else T
    ctx: Dict[str, Any] = {"positions": positions}
    if gid0 is not None:
        if is_paged(state):
            NP, ps, pps = paged_dims(state)
            S = pps * ps                    # logical capacity per slot
            ctx["paged"] = True
            ctx["page_table"] = state["page_table"]
            ctx["slots"] = phys_slots(state["page_table"],
                                      write_slots(cfg, S, cur, T), ps, NP)
        else:
            S = state["groups"][gid0]["k"].shape[2]
            ctx["slots"] = write_slots(cfg, S, cur, T)
        ctx["cache_pos"] = key_positions(cfg, S, cur)   # pre-write owners
        ctx["cur_len"] = cur        # scalar-prefetch operand (Pallas backend)
    mode = "decode"
    if n_commit is not None:
        mode = "replay"
        ctx["n_commit"] = n_commit
        if gid0 is not None:
            ctx["gate"] = jnp.arange(T)[None, :] < n_commit[:, None]
    x = _embed(params, cfg, tokens, None)
    x, new_groups, _ = run_stack(params, cfg, x, mode, state, ctx)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = lm_logits(params["embed"], x, cfg)
    adv = n_commit if n_commit is not None else T
    new_state = {**state, "cur_len": cur + adv,
                 "groups": {**state["groups"], **new_groups}}
    return logits, new_state


def verify(params: Params, cfg: ModelConfig, state: State,
           tokens: jnp.ndarray, pos_off=None,
           tail_mask=None) -> Tuple[jnp.ndarray, Dict]:
    """The paper's batched verification call.

    tokens: (B, k, w+1) — row i is [last_token, draft_i(0..w-1)].
    Returns (logits (B, k, w+1, V) f32, kv_tails for attention groups).
    State is NOT advanced (pure read).

    Tree mode (DESIGN.md §11) passes the whole token tree as the single row
    k == 1 with two STATIC topology constants:
      pos_off:   (w+1,) int numpy array — per-node position offset (tree
                 LEVEL, 0 for the committed last token) replacing the linear
                 arange; node i gets absolute position cur + pos_off[i].
      tail_mask: (w+1, w+1) bool numpy array — ancestor-or-self visibility
                 between tree nodes, threaded to the attention tail mask.
    Recurrent mixers run verify rows as causal SEQUENCES, which has no valid
    tree layout — callers gate tree mode on ``not has_recurrent(cfg)``
    (core/spec_engine.py raises at config validation).
    """
    B, K, W1 = tokens.shape
    cur = state["cur_len"]
    if pos_off is None:
        positions = make_positions(cfg, B, W1, offset=cur)
    else:
        pos = (jnp.asarray(pos_off, jnp.int32)[None, :]
               + cur[:, None])                            # (B, W1)
        if cfg.rope == MROPE:
            pos = jnp.broadcast_to(pos[None], (3, B, W1))
        positions = pos
    gid0 = next((gid for gid, s, _ in group_ids(cfg) if s.mixer == ATTN), None)
    ctx: Dict[str, Any] = {"positions": positions, "k_rows": K,
                           "tail_mask": tail_mask}
    if gid0 is not None:
        if is_paged(state):
            _, ps, pps = paged_dims(state)
            S = pps * ps
            ctx["paged"] = True
            ctx["page_table"] = state["page_table"]
        else:
            S = state["groups"][gid0]["k"].shape[2]
        ctx["cache_pos"] = key_positions(cfg, S, cur)
        ctx["cur_len"] = cur        # scalar-prefetch operand (Pallas backend)
    x = _embed(params, cfg, tokens.reshape(B * K, W1), None)
    x, kv_tails, _ = run_stack(params, cfg, x, "verify", state, ctx)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = lm_logits(params["embed"], x, cfg)
    return logits.reshape(B, K, W1, -1), kv_tails


def commit_kv_tails(cfg: ModelConfig, state: State, kv_tails: Dict,
                    winner: jnp.ndarray, n_commit: jnp.ndarray) -> State:
    """Fast commit for attention-only archs: write the winning row's accepted
    KV tail into the shared cache (no replay forward needed).  Paged states
    route the same gated write through each slot's page table."""
    cur = state["cur_len"]
    groups = dict(state["groups"])
    paged = is_paged(state)
    if paged:
        NP, ps, pps = paged_dims(state)
        S = pps * ps
    else:
        gid0 = next(gid for gid, s, _ in group_ids(cfg) if s.mixer == ATTN)
        S = state["groups"][gid0]["k"].shape[2]
    for gid, tails in kv_tails.items():
        k_t, v_t = tails["k_tail"], tails["v_tail"]  # (R,B,K,W1,KV,hd)
        R, B, K, W1 = k_t.shape[:4]
        wsel = winner.reshape(1, B, 1, 1, 1, 1)
        k_w = jnp.take_along_axis(k_t, wsel, axis=2)[:, :, 0]  # (R,B,W1,KV,hd)
        v_w = jnp.take_along_axis(v_t, wsel, axis=2)[:, :, 0]
        slots = write_slots(cfg, S, cur, W1)
        gate = jnp.arange(W1)[None, :] < n_commit[:, None]
        if paged:
            phys = phys_slots(state["page_table"], slots, ps, NP)
            kc, vc = jax.vmap(
                lambda kp, vp, kn, vn: paged_kv_write(kp, vp, kn, vn, phys,
                                                      gate=gate)
            )(state["groups"][gid]["k"], state["groups"][gid]["v"], k_w, v_w)
        else:
            kc, vc = jax.vmap(
                lambda kcache, vcache, kn, vn: kv_write(kcache, vcache,
                                                        kn, vn, slots,
                                                        gate=gate)
            )(state["groups"][gid]["k"], state["groups"][gid]["v"], k_w, v_w)
        groups[gid] = {"k": kc, "v": vc}
    return {**state, "cur_len": cur + n_commit, "groups": groups}


def _pure_recurrent(cfg: ModelConfig) -> bool:
    return all(b.mixer != ATTN for b in layer_blocks(cfg))
