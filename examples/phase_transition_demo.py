"""The paper's §3 phase-transition analysis for TPU v5e (Fig. 1 analogue).

Prints the roofline-modeled slowdown of a (k, w+1) verification call vs a
plain decode call for Mistral-7B, over context lengths — showing where the
'free verification' assumption breaks, and how the bifurcated (shared-cache)
layout pushes the boundary vs the paper's replicated-cache layout.

Run:  PYTHONPATH=src python examples/phase_transition_demo.py
"""
from repro.configs import get_config
from repro.core.phase import slowdown, verify_call_cost

cfg = get_config("mistral-7b")
print(f"model: {cfg.name}  (TPU v5e roofline model)\n")
print("ell      (k,w)=(5,4)   (10,10)    (25,14)   [shared-cache]")
for ell in (25, 100, 500, 4096, 32768):
    row = [f"{slowdown(cfg, ell, k, w):8.2f}x"
           for (k, w) in ((5, 4), (10, 10), (25, 14))]
    print(f"{ell:6d} " + "  ".join(row))
print("\nsame, paper's replicated-cache layout (k x KV reads):")
for ell in (500, 4096, 32768):
    row = [f"{slowdown(cfg, ell, k, w, shared_cache=False):8.2f}x"
           for (k, w) in ((5, 4), (10, 10), (25, 14))]
    print(f"{ell:6d} " + "  ".join(row))
c = verify_call_cost(cfg, 4096, 10, 10)
print(f"\n(10,10)@4k: {c.flops/1e9:.1f} GFLOP, {c.hbm_bytes/1e9:.2f} GB "
      f"-> {'compute' if c.compute_bound else 'memory'}-bound")
