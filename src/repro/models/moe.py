"""Mixture-of-Experts layer: top-k router, shared experts, two dispatch impls.

Covers Mixtral (8e top-2), DeepSeek-MoE (2 shared + 64 routed top-6,
fine-grained expert width) and Jamba (16e top-2).

Dispatch implementations:
  - ``dense``:   every expert computes every token, combined with router
                 weights.  O(E) FLOPs — used only as the correctness oracle
                 in tests and for tiny models.
  - ``scatter``: sort-based dropless-ish dispatch with capacity (the MaxText
                 approach): token-slots are sorted by expert id, packed into
                 an (E, C, d) buffer, batched expert matmuls, then combined
                 back.  Active-FLOPs-faithful, shards over the ``model`` axis
                 on the expert dimension, and is what the roofline sees.

Router aux loss (load balancing, Switch-style) is returned for training.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init

Params = Dict[str, jnp.ndarray]


def init_moe(rng, cfg: ModelConfig) -> Params:
    d, e_ff = cfg.d_model, cfg.expert_d_ff
    E = cfg.num_experts
    dt = cfg.param_dtype
    ks = jax.random.split(rng, 5)
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, e_ff), dt),
        "w_up": dense_init(ks[2], (E, d, e_ff), dt),
        "w_down": dense_init(ks[3], (E, e_ff, d), dt),
    }
    if cfg.num_shared_experts:
        s_ff = e_ff * cfg.num_shared_experts
        ks2 = jax.random.split(ks[4], 3)
        p["shared_gate"] = dense_init(ks2[0], (d, s_ff), dt)
        p["shared_up"] = dense_init(ks2[1], (d, s_ff), dt)
        p["shared_down"] = dense_init(ks2[2], (s_ff, d), dt)
    return p


def _expert_ffn(wg, wu, wd, x, cd):
    """x: (E, C, d) -> (E, C, d) batched SwiGLU over experts."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, wg.astype(cd)))
    u = jnp.einsum("ecd,edf->ecf", x, wu.astype(cd))
    return jnp.einsum("ecf,efd->ecd", g * u, wd.astype(cd))


def _router(params: Params, x2d: jnp.ndarray, cfg: ModelConfig):
    """Returns (topk_idx (N,K), topk_w (N,K), aux_loss scalar)."""
    logits = x2d.astype(jnp.float32) @ params["router"]           # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    topk_w = topk_w / jnp.maximum(topk_w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load balance loss
    E = cfg.num_experts
    me = probs.mean(axis=0)                                        # (E,)
    ce = jnp.zeros((E,)).at[topk_idx.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(ce.sum(), 1.0)
    aux = E * jnp.sum(me * ce)
    return topk_idx, topk_w, aux


def moe_dense(params: Params, x: jnp.ndarray, cfg: ModelConfig):
    """Oracle: all experts on all tokens. x: (B,T,d)."""
    cd = cfg.compute_dtype
    B, T, d = x.shape
    x2d = x.reshape(-1, d).astype(cd)
    idx, w, aux = _router(params, x2d, cfg)
    E = cfg.num_experts
    outs = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"],
                       jnp.broadcast_to(x2d, (E,) + x2d.shape), cd)  # (E,N,d)
    onehot = jax.nn.one_hot(idx, E, dtype=cd) * w.astype(cd)[..., None]
    comb = jnp.einsum("nke,end->nd", onehot, outs)
    return comb.reshape(B, T, d), aux


def moe_scatter(params: Params, x: jnp.ndarray, cfg: ModelConfig):
    """Sort-based capacity dispatch. x: (B,T,d)."""
    cd = cfg.compute_dtype
    B, T, d = x.shape
    N = B * T
    K = cfg.num_experts_per_tok
    E = cfg.num_experts
    C = max(int(N * K / E * cfg.capacity_factor), K)
    x2d = x.reshape(N, d).astype(cd)
    idx, w, aux = _router(params, x2d, cfg)                        # (N,K)
    flat_e = idx.reshape(-1)                                       # (N*K,)
    flat_t = jnp.repeat(jnp.arange(N), K)
    flat_w = w.reshape(-1)
    # position of each slot within its expert (stable over token order)
    order = jnp.argsort(flat_e, stable=True)
    ranks = jnp.zeros((N * K,), jnp.int32)
    seg = jax.nn.one_hot(flat_e[order], E, dtype=jnp.int32)
    pos_sorted = jnp.cumsum(seg, axis=0)[jnp.arange(N * K), flat_e[order]] - 1
    ranks = ranks.at[order].set(pos_sorted)
    keep = ranks < C
    # scatter tokens into (E, C, d)
    buf = jnp.zeros((E, C, d), cd)
    e_idx = jnp.where(keep, flat_e, 0)
    c_idx = jnp.where(keep, ranks, 0)
    vals = jnp.where(keep[:, None], x2d[flat_t], 0)
    buf = buf.at[e_idx, c_idx].add(vals)
    out_buf = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"],
                          buf, cd)                                  # (E,C,d)
    gathered = out_buf[e_idx, c_idx]                                # (N*K, d)
    gathered = jnp.where(keep[:, None], gathered, 0) * flat_w[:, None].astype(cd)
    comb = jnp.zeros((N, d), cd).at[flat_t].add(gathered)
    return comb.reshape(B, T, d), aux


def apply_moe(params: Params, x: jnp.ndarray, cfg: ModelConfig):
    """Returns (y, aux_loss). Adds shared experts (DeepSeek) when present."""
    impl = moe_dense if cfg.moe_impl == "dense" else moe_scatter
    y, aux = impl(params, x, cfg)
    if cfg.num_shared_experts:
        cd = cfg.compute_dtype
        xs = x.astype(cd)
        g = jax.nn.silu(xs @ params["shared_gate"].astype(cd))
        u = xs @ params["shared_up"].astype(cd)
        y = y + (g * u) @ params["shared_down"].astype(cd)
    return y, aux
