"""ShapeDtypeStruct stand-ins + step functions for the dry-run matrix.

For each (architecture, input-shape) pair this module builds:
  - the step function the production launcher would pjit
      train_4k    -> train_step          (decoders: token batch;
                                          audio/vlm: frontend-stub embeds)
      prefill_32k -> prefill_step        (last-position logits only)
      decode_32k  -> serve_step          (1 new token, 32k KV cache) and
                     spec_serve_step     (the paper: (k, w+1) verification)
      long_500k   -> serve_step at 524k  (SSM native / sliding-window ring)
  - abstract inputs (jax.ShapeDtypeStruct — no allocation ever happens)
  - in/out shardings from distributed/sharding.py

Skips (DESIGN.md §5): encoder-only archs have no decode; long_500k uses the
+swa ring-cache variant for full-attention dense archs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import get_config, long_context_variant
from ..distributed import sharding as shd
from ..models import model as M
from ..models.config import MROPE, ModelConfig
from ..train import AdamWConfig, make_train_step
from ..train.optimizer import init_opt_state

SHAPES: Dict[str, Dict] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# the paper's representative default (k, w) = (10, 10)
SPEC_K, SPEC_W = 10, 10


class DryrunCase(NamedTuple):
    name: str
    fn: Callable                 # positional-arg function to jit
    args: Tuple[Any, ...]        # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    skip_reason: Optional[str] = None
    donate: Tuple[int, ...] = ()   # argnums donated (train state, KV caches)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _abstract(fn, *args, **kwargs):
    return jax.eval_shape(functools.partial(fn, **kwargs), *args)


def params_abstract(cfg: ModelConfig):
    rng = _sds((2,), jnp.uint32)
    return _abstract(lambda r: M.init_params(r, cfg), rng)


def state_abstract(cfg: ModelConfig, batch: int, max_len: int):
    return _abstract(lambda: M.init_state(cfg, batch, max_len))


def _shardings_like(mesh, tree, rule):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, rule(mesh, p, l)), tree)


def resolve_case(arch: str, shape: str, mesh: Mesh,
                 spec_step: bool = False,
                 num_layers: Optional[int] = None) -> DryrunCase:
    """Build the (possibly skipped) dry-run case for one (arch, shape).

    ``num_layers`` overrides depth (roofline calibration compiles reduced
    1-period / 2-period variants with scans unrolled; see dryrun.py).
    """
    info = SHAPES[shape]
    cfg = get_config(arch)
    name = f"{arch}|{shape}" + ("|spec" if spec_step else "")

    if cfg.encoder_only and info["kind"] == "decode":
        return DryrunCase(name, None, (), (), None,
                          skip_reason="encoder-only: no decode step "
                                      "(DESIGN.md §5)")
    if shape == "long_500k":
        cfg = long_context_variant(cfg)
    if num_layers is not None:
        cfg = dataclasses.replace(cfg, num_layers=num_layers).validate()

    B, T = info["batch"], info["seq"]
    p_abs = params_abstract(cfg)
    p_shd = shd.params_shardings(mesh, p_abs)
    repl = shd.replicated(mesh)

    if info["kind"] == "train":
        opt_cfg = AdamWConfig(total_steps=1000)
        step = make_train_step(cfg, opt_cfg, remat=True)
        ts_abs = {"params": p_abs,
                  "opt": _abstract(lambda: init_opt_state(p_abs))}
        ts_shd = {"params": p_shd,
                  "opt": {"m": p_shd, "v": p_shd, "step": repl}}
        if cfg.embedding_inputs:
            emb = _sds((B, T, cfg.d_model), jnp.bfloat16)
            tgt = _sds((B, T), jnp.int32)
            batch_abs = (emb, tgt)
            batch_shd = (shd.batch_sharding(mesh, emb.shape),
                         shd.batch_sharding(mesh, tgt.shape))
        else:
            batch_abs = _sds((B, T + 1), jnp.int32)
            batch_shd = shd.batch_sharding(mesh, batch_abs.shape)
        return DryrunCase(name, step, (ts_abs, batch_abs),
                          (ts_shd, batch_shd), (ts_shd, repl), donate=(0,))

    if info["kind"] == "prefill":
        st_abs = state_abstract(cfg, B, T)
        st_shd = shd.state_shardings(mesh, st_abs)

        if cfg.embedding_inputs:
            def fn(params, state, embeds):
                return M.prefill(params, cfg, state, embeds=embeds,
                                 last_only=True)
            x_abs = _sds((B, T, cfg.d_model), jnp.bfloat16)
        else:
            def fn(params, state, tokens):
                return M.prefill(params, cfg, state, tokens=tokens,
                                 last_only=True)
            x_abs = _sds((B, T), jnp.int32)
        x_shd = shd.batch_sharding(mesh, x_abs.shape)
        return DryrunCase(name, fn, (p_abs, st_abs, x_abs),
                          (p_shd, st_shd, x_shd), (repl, st_shd),
                          donate=(1,))

    # decode kinds ---------------------------------------------------------
    st_abs = state_abstract(cfg, B, T)
    st_shd = shd.state_shardings(mesh, st_abs)
    if not spec_step:
        def fn(params, state, tokens):
            logits, st = M.decode(params, cfg, state, tokens)
            # serve semantics: the step emits the next token, not the full
            # (B, vocab) logits — keeps the vocab-sharded lm head local
            # (argmax = local argmax + tiny cross-shard reduce, §Perf it-8)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), st
        t_abs = _sds((B, 1), jnp.int32)
        t_shd = shd.batch_sharding(mesh, t_abs.shape)
        return DryrunCase(name, fn, (p_abs, st_abs, t_abs),
                          (p_shd, st_shd, t_shd), (repl, st_shd),
                          donate=(1,))

    # the paper's speculative verification step (k, w+1)
    def fn(params, state, rows):
        logits, tails = M.verify(params, cfg, state, rows)
        # greedy acceptance happens on-device in the engine; for lowering we
        # return the argmax (the big tensors stay sharded)
        return jnp.argmax(logits, axis=-1), tails
    r_abs = _sds((B, SPEC_K, SPEC_W + 1), jnp.int32)
    r_shd = shd.batch_sharding(mesh, r_abs.shape)
    return DryrunCase(name, fn, (p_abs, st_abs, r_abs),
                      (p_shd, st_shd, r_shd), None)
