"""Model-derived N-gram tables (paper §4.1).

All three tables are learning-free (P1), use no external data (P2) and are
one-off costs amortised over the whole serving lifetime:

  - *unigram*:  rank tokens by the distance of their output embedding from
    the mean output embedding, under the metric induced by the covariance of
    the input embeddings:  d(x) = ||u_x - ū||_V  with
    <a, b>_V = aᵀ (VᵀV/|X|) b,  p(x) ∝ exp(-d(x)).
    (The paper's Appendix B code ranks by the *inner product* mū·u_x instead
    of the distance; we implement the main-text distance formula and keep the
    appendix variant selectable for ablation.)
  - *bigram*:   p_M(·|x) for every x — one batched forward sweep over the
    vocabulary, stored as a top-k index table (V, k_max).
  - *extended bigram*:  greedy argmax chains of the bigram, so a draft of any
    w > 1 is an O(1) lookup (V, w_max).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class NGramTables:
    """Static draft tables, treated as (abstract-shardable) model inputs."""
    unigram_topk: jnp.ndarray      # (k_max,) int32 — global token ranking
    bigram_topk: jnp.ndarray       # (V, k_max) int32 — top-k of p_M(.|x)
    bigram_chain: jnp.ndarray      # (V, w_max) int32 — argmax chains

    @property
    def k_max(self) -> int:
        return self.bigram_topk.shape[-1]

    @property
    def w_max(self) -> int:
        return self.bigram_chain.shape[-1]


def abstract_tables(vocab_size: int, k_max: int = 32,
                    w_max: int = 16) -> "jax.ShapeDtypeStruct tree":
    """ShapeDtypeStruct stand-ins for the dry-run (launch/input_specs.py)."""
    return NGramTables(
        unigram_topk=jax.ShapeDtypeStruct((k_max,), jnp.int32),
        bigram_topk=jax.ShapeDtypeStruct((vocab_size, k_max), jnp.int32),
        bigram_chain=jax.ShapeDtypeStruct((vocab_size, w_max), jnp.int32),
    )


def build_unigram(embedding: jnp.ndarray, lm_head: jnp.ndarray,
                  k_max: int = 32, appendix_variant: bool = False
                  ) -> jnp.ndarray:
    """embedding: (V, d) input embeddings V; lm_head: (d, V) output embeds U.

    Returns the k_max tokens with the smallest d(x) (main-text formula), or
    the appendix's topk(-(mū·Cov·u_x)) when ``appendix_variant``.
    """
    Ve = embedding.astype(jnp.float32)
    U = lm_head.astype(jnp.float32)            # columns u_x: (d, V)
    cov = (Ve.T @ Ve) / Ve.shape[0]            # (d, d)
    mu = U.mean(axis=1, keepdims=True)         # (d, 1)
    if appendix_variant:
        dists = (mu.T @ cov @ U).squeeze(0)    # (V,)
        return jax.lax.top_k(-dists, k_max)[1].astype(jnp.int32)
    diff = U - mu                              # (d, V)
    d2 = jnp.einsum("dv,de,ev->v", diff, cov, diff)
    return jax.lax.top_k(-d2, k_max)[1].astype(jnp.int32)


def build_bigram(next_logits_fn: Callable[[jnp.ndarray], jnp.ndarray],
                 vocab_size: int, k_max: int = 32, w_max: int = 16,
                 batch: int = 256) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sweep the vocabulary once to obtain p_M(.|x) for every token.

    next_logits_fn: (B, 1) int32 -> (B, V) f32 next-token logits (a jitted
    single-token model forward; the KV-less call the paper uses).
    Returns (bigram_topk (V, k_max), bigram_chain (V, w_max)).
    """
    n_batches = -(-vocab_size // batch)
    topks = []
    for i in range(n_batches):
        lo = i * batch
        toks = jnp.clip(jnp.arange(lo, lo + batch), 0, vocab_size - 1)
        logits = next_logits_fn(toks[:, None])
        topks.append(jax.lax.top_k(logits, k_max)[1].astype(jnp.int32))
    topk = jnp.concatenate(topks, axis=0)[:vocab_size]      # (V, k_max)
    return topk, chain_from_argmax(topk[:, 0], w_max)


def chain_from_argmax(argmax_next: jnp.ndarray, w_max: int) -> jnp.ndarray:
    """argmax_next: (V,) -> chain (V, w_max): chain[x, j] = argmax^(j+1)(x)."""
    cols = [argmax_next]
    for _ in range(w_max - 1):
        cols.append(argmax_next[cols[-1]])
    return jnp.stack(cols, axis=1).astype(jnp.int32)


def tables_from_counts(counts: jnp.ndarray, k_max: int = 32,
                       w_max: int = 16) -> NGramTables:
    """Build tables from an empirical bigram count matrix (V, V).

    Used in tests/benchmarks to get *exact* ground-truth tables for tiny
    vocabularies without a model sweep.
    """
    V = counts.shape[0]
    k_max = min(k_max, V)
    topk = jax.lax.top_k(counts.astype(jnp.float32), k_max)[1].astype(jnp.int32)
    uni = jax.lax.top_k(counts.sum(0).astype(jnp.float32),
                        k_max)[1].astype(jnp.int32)
    return NGramTables(unigram_topk=uni, bigram_topk=topk,
                       bigram_chain=chain_from_argmax(topk[:, 0], w_max))
