"""HuBERT-XLarge: encoder-only transformer backbone (same arch as wav2vec2)
[arXiv:2106.07447].  The conv/mel frontend is a STUB per the assignment —
input_specs() feeds precomputed frame embeddings; vocab=504 target units.
Encoder-only => bidirectional attention, no decode shapes (DESIGN.md §5)."""
import jax.numpy as jnp
from ..models.config import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", arch_type="audio", source="arXiv:2106.07447",
        num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
        d_ff=5120, vocab_size=504,
        block_pattern=(BlockSpec("attn", "gelu"),),
        norm="layernorm", rope="none", causal=False,
        encoder_only=True, embedding_inputs=True,
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-smoke", arch_type="audio", source="arXiv:2106.07447",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=64,
        block_pattern=(BlockSpec("attn", "gelu"),),
        norm="layernorm", rope="none", causal=False,
        encoder_only=True, embedding_inputs=True,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    ).validate()
