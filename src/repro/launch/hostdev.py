"""Host-platform placeholder-device override, applied BEFORE any jax import.

jax locks the device count on first init, so entry points that want a
multi-device CPU debug mesh (``--mesh`` in launch/serve.py and
benchmarks/continuous_batching.py, the sharded pytest lane, the dry-run)
must extend ``XLA_FLAGS`` before importing jax.  This module is
deliberately jax-free so it can run first.

The rules every caller of ``ensure_host_devices`` gets:
  - never clobber caller-provided ``XLA_FLAGS`` — APPEND the override;
  - never override a device count the caller already chose;
  - never touch the environment once jax is imported (too late to matter,
    and mutating it then would only mislead subprocesses).
"""
from __future__ import annotations

import math
import os
import sys
from typing import Optional, Tuple

_COUNT_FLAG = "xla_force_host_platform_device_count"


def parse_mesh_shape(s: str) -> Tuple[int, ...]:
    """"2x2" -> (2, 2); "2x2x2" -> (2, 2, 2).  2 axes = (data, model),
    3 = (pod, data, model) — launch/mesh.py names them."""
    try:
        dims = tuple(int(x) for x in s.lower().split("x"))
    except ValueError:
        raise ValueError(f"--mesh wants DxM (e.g. 2x2), got {s!r}")
    if len(dims) not in (2, 3) or any(d <= 0 for d in dims):
        raise ValueError(f"--mesh wants 2 or 3 positive dims, got {s!r}")
    return dims


def mesh_arg(argv=None) -> Optional[str]:
    """Early peek at ``--mesh`` (before argparse — which needs the module
    imported — and before the jax import locks the device count)."""
    argv = sys.argv if argv is None else argv
    for i, a in enumerate(argv):
        if a == "--mesh" and i + 1 < len(argv):
            return argv[i + 1]
        if a.startswith("--mesh="):
            return a.split("=", 1)[1]
    return None


def ensure_for_mesh_argv(argv=None) -> Optional[str]:
    """The whole --mesh bootstrap in one call: peek argv, parse the shape,
    provision placeholder devices for it.  Returns the raw --mesh string
    (None when absent).  Entry points call this under their
    ``if __name__ == "__main__"`` guard BEFORE importing jax."""
    m = mesh_arg(argv)
    if m:
        ensure_host_devices(math.prod(parse_mesh_shape(m)))
    return m


def ensure_host_devices(n: int) -> bool:
    """Append ``--xla_force_host_platform_device_count=n`` to XLA_FLAGS if
    no count is set yet and jax is not imported.  Returns whether the
    environment was changed."""
    if "jax" in sys.modules:
        return False     # device count already locked; mesh build will
                         # raise a clear error if there are too few devices
    flags = os.environ.get("XLA_FLAGS", "")
    if _COUNT_FLAG in flags:
        return False
    os.environ["XLA_FLAGS"] = f"{flags} --{_COUNT_FLAG}={n}".strip()
    return True
