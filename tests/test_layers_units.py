"""Unit tests: MoE dispatch, chunked scans, ring cache, RoPE variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as moe_lib
from repro.models.cache import key_positions, prefill_write, write_slots
from repro.models.config import BlockSpec, ModelConfig
from repro.models.mamba import init_mamba, mamba_mix, selective_scan
from repro.models.xlstm import (_mlstm_cell_chunkwise, _mlstm_cell_scan,
                                init_mlstm, mlstm_mix)

pytestmark = pytest.mark.slow  # model-level suite; excluded from the
                               # -m "not slow" fast lane

F32 = dict(param_dtype=jnp.float32, compute_dtype=jnp.float32)


# ---------------------------------------------------------------- MoE
def test_moe_scatter_matches_dense():
    cfg = ModelConfig(name="m", num_layers=1, d_model=32, num_heads=4,
                      num_kv_heads=4, d_ff=64, vocab_size=11, num_experts=4,
                      num_experts_per_tok=2, capacity_factor=4.0,  # no drops
                      **F32).validate()
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    yd, auxd = moe_lib.moe_dense(p, x, cfg)
    ys, auxs = moe_lib.moe_scatter(p, x, cfg)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(yd),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(auxs), float(auxd), rtol=1e-5)


def test_moe_shared_experts_added():
    cfg = ModelConfig(name="m", num_layers=1, d_model=32, num_heads=4,
                      num_kv_heads=4, d_ff=64, moe_d_ff=16, vocab_size=11,
                      num_experts=4, num_experts_per_tok=2,
                      num_shared_experts=2, **F32).validate()
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    assert p["shared_gate"].shape == (32, 32)  # 2 shared * e_ff 16
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 32))
    y, _ = moe_lib.apply_moe(p, x, cfg)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())


def test_moe_capacity_drops_are_bounded():
    """With tiny capacity, outputs stay finite and within combine weights."""
    cfg = ModelConfig(name="m", num_layers=1, d_model=16, num_heads=2,
                      num_kv_heads=2, d_ff=32, vocab_size=11, num_experts=2,
                      num_experts_per_tok=2, capacity_factor=0.25,
                      **F32).validate()
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16))
    y, _ = moe_lib.moe_scatter(p, x, cfg)
    assert bool(jnp.isfinite(y).all())


# ---------------------------------------------------------------- Mamba
def test_selective_scan_chunked_equals_unchunked():
    B, T, di, ds = 2, 32, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    u = jax.random.normal(ks[0], (B, T, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, di)))
    A = -jnp.exp(jax.random.normal(ks[2], (di, ds)) * 0.2)
    Bm = jax.random.normal(ks[3], (B, T, ds))
    Cm = jax.random.normal(ks[4], (B, T, ds))
    D = jnp.ones((di,))
    h0 = jnp.zeros((B, di, ds))
    y1, h1 = selective_scan(u, dt, A, Bm, Cm, D, h0, chunk=T)     # one chunk
    y2, h2 = selective_scan(u, dt, A, Bm, Cm, D, h0, chunk=8)     # 4 chunks
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-5, atol=1e-5)


def test_mamba_step_equals_full():
    """Processing a sequence in two segments == one full pass."""
    cfg = ModelConfig(name="m", num_layers=1, d_model=16, num_heads=2,
                      num_kv_heads=2, d_ff=32, vocab_size=11, **F32
                      ).validate()
    p = init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))
    conv0 = jnp.zeros((2, cfg.mamba_d_conv - 1, cfg.mamba_d_inner))
    ssm0 = jnp.zeros((2, cfg.mamba_d_inner, cfg.mamba_d_state))
    y_full, cf, sf = mamba_mix(p, x, cfg, conv0, ssm0)
    y1, c1, s1 = mamba_mix(p, x[:, :7], cfg, conv0, ssm0)
    y2, c2, s2 = mamba_mix(p, x[:, 7:], cfg, c1, s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(sf),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- xLSTM
def test_mlstm_chunkwise_equals_scan():
    B, T, H, dh = 2, 64, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, T, H, dh))
    k = jax.random.normal(ks[1], (B, T, H, dh))
    v = jax.random.normal(ks[2], (B, T, H, dh))
    li = jax.random.normal(ks[3], (B, T, H)) - 2.0
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, T, H)) + 2.0)
    C0 = jnp.zeros((B, H, dh, dh))
    n0 = jnp.zeros((B, H, dh))
    m0 = jnp.full((B, H), -1e9)
    h1, (C1, nn1, m1) = _mlstm_cell_scan(q, k, v, li, lf, C0, n0, m0)
    h2, (C2, nn2, m2) = _mlstm_cell_chunkwise(q, k, v, li, lf, C0, n0, m0,
                                              chunk=16)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-4, atol=2e-4)
    # states may differ by stabiliser offset; compare descaled C
    np.testing.assert_allclose(np.asarray(C1 * jnp.exp(m1)[..., None, None]),
                               np.asarray(C2 * jnp.exp(m2)[..., None, None]),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------- cache
def test_ring_key_positions():
    cfg = ModelConfig(name="r", num_layers=1, d_model=16, num_heads=2,
                      num_kv_heads=2, d_ff=32, vocab_size=11,
                      sliding_window=4, **F32).validate()
    S = 4
    pos = key_positions(cfg, S, jnp.asarray([0, 3, 4, 7]))
    np.testing.assert_array_equal(np.asarray(pos[0]), [-1, -1, -1, -1])
    np.testing.assert_array_equal(np.asarray(pos[1]), [0, 1, 2, -1])
    np.testing.assert_array_equal(np.asarray(pos[2]), [0, 1, 2, 3])
    np.testing.assert_array_equal(np.asarray(pos[3]), [4, 5, 6, 3])


def test_ring_write_slots_wrap():
    cfg = ModelConfig(name="r", num_layers=1, d_model=16, num_heads=2,
                      num_kv_heads=2, d_ff=32, vocab_size=11,
                      sliding_window=4, **F32).validate()
    slots = write_slots(cfg, 4, jnp.asarray([3]), 3)
    np.testing.assert_array_equal(np.asarray(slots[0]), [3, 0, 1])


def test_prefill_write_longer_than_ring():
    cfg = ModelConfig(name="r", num_layers=1, d_model=16, num_heads=2,
                      num_kv_heads=1, d_ff=32, vocab_size=11,
                      sliding_window=4, **F32).validate()
    B, T, S, KV, hd = 1, 7, 4, 1, 8
    kc = jnp.zeros((B, S, KV, hd))
    vc = jnp.zeros((B, S, KV, hd))
    k_new = jnp.arange(T, dtype=jnp.float32)[None, :, None, None] * jnp.ones(
        (B, T, KV, hd))
    kc2, _ = prefill_write(cfg, kc, vc, k_new, k_new)
    # slot s holds the largest pos < 7 with pos % 4 == s -> [4, 5, 6, 3]
    np.testing.assert_array_equal(np.asarray(kc2[0, :, 0, 0]), [4, 5, 6, 3])
