"""jit'd public wrappers around the Pallas kernels.

These handle layout transposition, cache/block padding and batching only;
backend SELECTION (xla vs pallas, interpret forcing, eligibility) lives one
level up in ``kernels/dispatch.py``, which is what production code calls.
On this CPU container the kernels run with ``interpret=True``; on a real
TPU the default resolves to ``interpret=False``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .ngram_match import DEFAULT_BLOCK_L, ngram_match_call
from .spec_attention import (DEFAULT_BLOCK_S, paged_spec_attention_call,
                             spec_attention_call)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value=0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), size


@functools.partial(jax.jit,
                   static_argnames=("w1", "block_s", "interpret",
                                    "tail_mask"))
def spec_attention_op(q, k_cache, v_cache, k_tail, v_tail, cur_len, *,
                      w1: int, block_s: int = DEFAULT_BLOCK_S,
                      interpret: bool | None = None,
                      tail_mask=None) -> jnp.ndarray:
    """Engine-facing layout: q (B,K,W1,H,hd); caches (B,S,KV,hd);
    tails (B,K,W1,KV,hd); cur_len (B,).  Returns (B,K,W1,H,hd).

    ``tail_mask``: optional STATIC tail-visibility matrix as a hashable
    tuple-of-tuples of bool (a topology constant, so it is part of the jit
    cache key on purpose — dispatch.py converts from numpy)."""
    if interpret is None:
        interpret = _default_interpret()
    B, K, W1, H, hd = q.shape
    S = k_cache.shape[1]
    KV = k_cache.shape[2]
    qk = q.transpose(0, 3, 1, 2, 4).reshape(B, H, K * W1, hd)
    kc = k_cache.transpose(0, 2, 1, 3)           # (B,KV,S,hd)
    vc = v_cache.transpose(0, 2, 1, 3)
    kt = k_tail.transpose(0, 3, 1, 2, 4).reshape(B, KV, K * W1, hd)
    vt = v_tail.transpose(0, 3, 1, 2, 4).reshape(B, KV, K * W1, hd)
    bs = min(block_s, S)
    kc, S0 = _pad_to(kc, 2, bs)
    vc, _ = _pad_to(vc, 2, bs)
    tm = None if tail_mask is None else np.asarray(tail_mask, bool)
    # padded cache slots have slot >= S0 >= cur_len -> masked by cur_len test
    # (serving avoids the per-call repad by sizing its buffers through
    # dispatch.align_cache_len; arbitrary lengths stay correct here)
    out = spec_attention_call(qk, kc, vc, kt, vt, cur_len.astype(jnp.int32),
                              w1=W1, block_s=bs, interpret=interpret,
                              tail_mask=tm)
    return out.reshape(B, H, K, W1, hd).transpose(0, 2, 3, 1, 4)


@functools.partial(jax.jit, static_argnames=("w1", "interpret", "tail_mask"))
def paged_spec_attention_op(q, k_pool, v_pool, page_table, k_tail, v_tail,
                            cur_len, *, w1: int,
                            interpret: bool | None = None,
                            tail_mask=None) -> jnp.ndarray:
    """Engine-facing paged layout: q (B,K,W1,H,hd);
    pools (num_pages, page_size, KV, hd); page_table (B, pages_per_slot);
    tails (B,K,W1,KV,hd); cur_len (B,); tail_mask as in spec_attention_op.
    Returns (B,K,W1,H,hd).

    No cache padding path exists here on purpose: the pool is whole pages by
    construction (page_size == the kernel's block_s), which is exactly why
    the paged layout is free for this kernel (DESIGN.md §8).
    """
    if interpret is None:
        interpret = _default_interpret()
    B, K, W1, H, hd = q.shape
    KV = k_pool.shape[2]
    qk = q.transpose(0, 3, 1, 2, 4).reshape(B, H, K * W1, hd)
    kp = k_pool.transpose(0, 2, 1, 3)            # (NP, KV, ps, hd)
    vp = v_pool.transpose(0, 2, 1, 3)
    kt = k_tail.transpose(0, 3, 1, 2, 4).reshape(B, KV, K * W1, hd)
    vt = v_tail.transpose(0, 3, 1, 2, 4).reshape(B, KV, K * W1, hd)
    tm = None if tail_mask is None else np.asarray(tail_mask, bool)
    out = paged_spec_attention_call(qk, kp, vp,
                                    page_table.astype(jnp.int32), kt, vt,
                                    cur_len.astype(jnp.int32), w1=W1,
                                    interpret=interpret, tail_mask=tm)
    return out.reshape(B, H, K, W1, hd).transpose(0, 2, 3, 1, 4)


def spec_attention_ref_op(q, k_cache, v_cache, k_tail, v_tail, cur_len, *,
                          w1: int, tail_mask=None) -> jnp.ndarray:
    """Oracle with the same engine-facing layout."""
    B, K, W1, H, hd = q.shape
    KV = k_cache.shape[2]
    qk = q.transpose(0, 3, 1, 2, 4).reshape(B, H, K * W1, hd)
    kc = k_cache.transpose(0, 2, 1, 3)
    vc = v_cache.transpose(0, 2, 1, 3)
    kt = k_tail.transpose(0, 3, 1, 2, 4).reshape(B, KV, K * W1, hd)
    vt = v_tail.transpose(0, 3, 1, 2, 4).reshape(B, KV, K * W1, hd)
    tm = None if tail_mask is None else np.asarray(tail_mask, bool)
    out = ref.spec_attention_ref(qk, kc, vc, kt, vt,
                                 cur_len.astype(jnp.int32), w1=W1,
                                 tail_mask=tm)
    return out.reshape(B, H, K, W1, hd).transpose(0, 2, 3, 1, 4)


@functools.partial(jax.jit, static_argnames=("w", "block_l", "interpret"))
def ngram_match_op(buf, query, cur_len, *, w: int,
                   block_l: int = DEFAULT_BLOCK_L,
                   interpret: bool | None = None):
    """buf: (B, L) int32; query: (B, q); cur_len: (B,).

    Returns (match (B, L) int32, hash (B, L) uint32)."""
    if interpret is None:
        interpret = _default_interpret()
    B, L = buf.shape
    q = query.shape[1]
    bl = min(block_l, L)
    Lp = -(-L // bl) * bl           # pad positions to whole blocks; padded
    pad = jnp.full((B, Lp - L + q + w), -1, jnp.int32)   # slots can never
    bufp = jnp.concatenate([buf.astype(jnp.int32), pad], axis=1)  # match
    fn = lambda b, qq, c: ngram_match_call(b, qq, c[None], w=w, block_l=bl,
                                           interpret=interpret)
    m, h = jax.vmap(fn)(bufp, query.astype(jnp.int32),
                        cur_len.astype(jnp.int32))
    return m[:, :L], h[:, :L]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "block_d", "interpret"))
def mamba_scan_op(u, dt, A, B, C, D, h0, *, chunk: int = 128,
                  block_d: int = 512, interpret: bool | None = None):
    """Padded/clamped wrapper for the chunked selective-scan kernel."""
    from .mamba_scan import mamba_scan_call
    if interpret is None:
        interpret = _default_interpret()
    Bt, T, di = u.shape
    c = min(chunk, T) if T % min(chunk, T) == 0 else T
    bd = min(block_d, di) if di % min(block_d, di) == 0 else di
    return mamba_scan_call(u, dt, A, B, C, D, h0, chunk=c, block_d=bd,
                           interpret=interpret)
