import jax
import jax.numpy as jnp
import pytest

from repro.models.config import BlockSpec, ModelConfig

# NOTE: no XLA_FLAGS device-count override here on purpose — smoke tests and
# benches must see the single real CPU device (the 512-device placeholder
# mesh exists ONLY inside repro/launch/dryrun.py).

F32 = dict(param_dtype=jnp.float32, compute_dtype=jnp.float32)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long model-level suite; deselect with -m 'not slow' for the "
        "inner-loop fast lane (tier-1 verification still runs everything)")


@pytest.fixture(scope="module", autouse=True)
def _drop_jit_caches_per_module():
    """Drop jax's compiled-executable caches when a test module finishes.

    Tier-1 runs the whole suite in ONE process and every module compiles
    its own model configs, so the process-global executable cache only
    grows — past a few hundred retained executables XLA:CPU's compiler has
    been observed to segfault mid-compile (deep in backend_compile, late
    in the run).  Cross-module cache reuse is ~nil (each module names its
    own cfg precisely so it gets a fresh cache), so clearing at module
    teardown bounds the growth without re-compiling anything a module
    still needs."""
    yield
    jax.clear_caches()


@pytest.fixture(autouse=True)
def _act_sharding_hygiene():
    """No test may leak an installed activation-sharder mesh into the next
    one: an installed mesh silently pins attn_verify off the Pallas path
    for the whole process (models/attention.py:_use_verify_kernel)."""
    yield
    from repro.distributed import act_sharding
    act_sharding.uninstall()


@pytest.fixture(scope="session")
def tiny_dense_cfg():
    return ModelConfig(name="tiny", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=2, d_ff=128, vocab_size=61,
                       **F32).validate()


@pytest.fixture(scope="session")
def tiny_hybrid_cfg():
    return ModelConfig(
        name="tiny-hyb", num_layers=4, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=61,
        num_experts=4, num_experts_per_tok=2,
        block_pattern=(BlockSpec("mamba", "swiglu"), BlockSpec("mamba", "moe"),
                       BlockSpec("attn", "swiglu"), BlockSpec("mamba", "moe")),
        **F32).validate()


@pytest.fixture(scope="session")
def tiny_xlstm_cfg():
    return ModelConfig(
        name="tiny-xl", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=0, rope="none",
        block_pattern=(BlockSpec("mlstm", "none"), BlockSpec("slstm", "none")),
        **F32).validate()


def make_params(cfg, seed=0):
    from repro.models import model as M
    return M.init_params(jax.random.PRNGKey(seed), cfg)


@pytest.fixture(scope="session")
def tiny_dense(tiny_dense_cfg):
    return tiny_dense_cfg, make_params(tiny_dense_cfg)
