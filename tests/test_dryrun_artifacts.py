"""Validate dry-run artifacts when present (deliverable e gate).

These tests are skipped until ``python -m repro.launch.dryrun --all`` has
produced experiments/dryrun/*.json; once present, every non-skip case must
have compiled, and skips must match the documented DESIGN.md §5 set.
"""
import glob
import json
import os

import pytest

ART_DIR = os.path.join(os.path.dirname(__file__), "..",
                       "experiments", "dryrun")

EXPECTED_SKIPS = {("hubert-xlarge", "decode_32k"),
                  ("hubert-xlarge", "long_500k")}


def _records(mesh_tag):
    files = glob.glob(os.path.join(ART_DIR, f"*__{mesh_tag}__base.json"))
    return [json.load(open(f)) for f in files]


@pytest.mark.parametrize("mesh_tag", ["pod", "multipod"])
def test_dryrun_matrix(mesh_tag):
    recs = _records(mesh_tag)
    if not recs:
        pytest.skip(f"no {mesh_tag} dry-run artifacts yet "
                    "(run python -m repro.launch.dryrun --all)")
    fails = [(r["arch"], r["shape"]) for r in recs
             if r.get("status") == "fail"]
    assert not fails, f"dry-run failures: {fails}"
    skips = {(r["arch"], r["shape"]) for r in recs
             if r.get("status") == "skip"}
    assert skips <= EXPECTED_SKIPS, f"unexpected skips: {skips}"
    oks = [r for r in recs if r.get("status") == "ok"]
    for r in oks:
        assert r["cost"].get("flops", 0) > 0, r["arch"]
        assert r["memory"].get("total_hbm_bytes", 0) > 0, r["arch"]


def test_pod_matrix_complete_when_present():
    recs = _records("pod")
    if len(recs) < 40:
        pytest.skip(f"pod matrix incomplete ({len(recs)}/40)")
    assert len(recs) == 40


# ---------------------------------------------------------------------------
# import hygiene: the dry-run's 512-device override must never leak out of
# its own entry point (regression: it used to clobber XLA_FLAGS at import,
# breaking jax device state for anything that imported the module)
# ---------------------------------------------------------------------------
def test_importing_dryrun_does_not_mutate_xla_flags():
    import importlib
    import sys
    before = os.environ.get("XLA_FLAGS")
    sys.modules.pop("repro.launch.dryrun", None)
    mod = importlib.import_module("repro.launch.dryrun")
    assert os.environ.get("XLA_FLAGS") == before, (
        "importing repro.launch.dryrun mutated XLA_FLAGS — the placeholder-"
        "device override may only apply when run as the dry-run script")
    assert mod.__doc__ and "Multi-pod dry-run" in mod.__doc__, (
        "the module docstring must stay FIRST (ahead of the entry-point "
        "guard) or help()/pydoc lose the documented usage")


def test_device_flag_appends_and_respects_caller(monkeypatch):
    """The one shared device-count policy (launch/hostdev.py, used by the
    dry-run and the --mesh entry points): append to caller XLA_FLAGS,
    never clobber; a caller-chosen count wins; refuse once jax is up."""
    import sys

    from repro.launch import hostdev
    # with jax imported (this process), the env must be left alone
    monkeypatch.setenv("XLA_FLAGS", "--marker")
    assert hostdev.ensure_host_devices(512) is False
    assert os.environ["XLA_FLAGS"] == "--marker"
    # pre-jax (simulated): caller flags are appended to, not clobbered
    monkeypatch.delitem(sys.modules, "jax")     # restored by monkeypatch
    monkeypatch.setenv("XLA_FLAGS", "--xla_cpu_enable_fast_math=false")
    assert hostdev.ensure_host_devices(512) is True
    assert os.environ["XLA_FLAGS"].startswith(
        "--xla_cpu_enable_fast_math=false ")
    assert "device_count=512" in os.environ["XLA_FLAGS"]
    # a caller-chosen device count wins outright
    monkeypatch.setenv("XLA_FLAGS",
                       "--xla_force_host_platform_device_count=8")
    assert hostdev.ensure_host_devices(512) is False
    assert os.environ["XLA_FLAGS"] == \
        "--xla_force_host_platform_device_count=8"
