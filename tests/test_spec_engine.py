"""THE paper invariant: speculative output == greedy output, always."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ngram_tables import (NGramTables, build_bigram, build_unigram)
from repro.core.spec_engine import SpecConfig, generate, greedy_reference
from repro.models import model as M

pytestmark = pytest.mark.slow  # model-level suite; excluded from -m 'not slow' fast lane


def _tables(params, cfg, k_max=8, w_max=8):
    fwd = jax.jit(lambda t: M.forward(params, cfg, tokens=t)[0][:, -1])
    topk, chain = build_bigram(fwd, cfg.vocab_size, k_max=k_max, w_max=w_max,
                               batch=cfg.vocab_size)
    uni = build_unigram(params["embed"]["embedding"],
                        params["embed"]["lm_head"], k_max=k_max)
    return NGramTables(uni, topk, chain)


@pytest.mark.parametrize("strategy", ["greedy", "bigram", "unigram",
                                      "context", "mixed"])
def test_spec_equals_greedy_dense(tiny_dense, strategy):
    cfg, params = tiny_dense
    tables = _tables(params, cfg)
    B, P, N = 2, 10, 24
    prompt = jax.random.randint(jax.random.PRNGKey(5), (B, P), 0,
                                cfg.vocab_size)
    ref = greedy_reference(params, cfg, prompt, N)
    spec = SpecConfig(k=4, w=3, q=1, strategy=strategy, max_new_tokens=N)
    buf, blen, stats = generate(params, cfg, spec, prompt, tables)
    for b in range(B):
        np.testing.assert_array_equal(np.asarray(buf[b, :P + N]),
                                      np.asarray(ref[b]))
    assert (np.asarray(blen) == P + N).all()
    assert (np.asarray(stats["tokens"]) == N).all()


@pytest.mark.parametrize("kw", [(1, 1), (2, 5), (8, 2)])
def test_spec_equals_greedy_kw_grid(tiny_dense, kw):
    cfg, params = tiny_dense
    k, w = kw
    tables = _tables(params, cfg, k_max=max(8, k), w_max=max(8, w))
    B, P, N = 2, 6, 16
    prompt = jax.random.randint(jax.random.PRNGKey(7), (B, P), 0,
                                cfg.vocab_size)
    ref = greedy_reference(params, cfg, prompt, N)
    spec = SpecConfig(k=k, w=w, strategy="mixed", max_new_tokens=N)
    buf, _, _ = generate(params, cfg, spec, prompt, tables)
    np.testing.assert_array_equal(np.asarray(buf[:, :P + N]), np.asarray(ref))


def test_spec_equals_greedy_recurrent(tiny_hybrid_cfg):
    cfg = tiny_hybrid_cfg
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tables = _tables(params, cfg)
    B, P, N = 2, 8, 16
    prompt = jax.random.randint(jax.random.PRNGKey(5), (B, P), 0,
                                cfg.vocab_size)
    ref = greedy_reference(params, cfg, prompt, N)
    spec = SpecConfig(k=3, w=3, strategy="mixed", max_new_tokens=N)
    buf, _, _ = generate(params, cfg, spec, prompt, tables)
    np.testing.assert_array_equal(np.asarray(buf[:, :P + N]), np.asarray(ref))


def test_eos_stops_generation(tiny_dense):
    cfg, params = tiny_dense
    tables = _tables(params, cfg)
    B, P, N = 1, 8, 32
    prompt = jax.random.randint(jax.random.PRNGKey(3), (B, P), 0,
                                cfg.vocab_size)
    ref = greedy_reference(params, cfg, prompt, N)[0, P:]
    eos = int(ref[5])  # force an eos hit mid-stream
    spec = SpecConfig(k=4, w=3, strategy="mixed", max_new_tokens=N,
                      eos_id=eos)
    buf, blen, _ = generate(params, cfg, spec, prompt, tables)
    out = np.asarray(buf[0, P:int(blen[0])])
    first = list(np.asarray(ref)).index(eos)
    np.testing.assert_array_equal(out, np.asarray(ref[:first + 1]))
    assert out[-1] == eos


def test_tokens_per_call_reporting(tiny_dense):
    cfg, params = tiny_dense
    tables = _tables(params, cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(9), (1, 8), 0,
                                cfg.vocab_size)
    spec = SpecConfig(k=4, w=4, strategy="mixed", max_new_tokens=20)
    _, _, stats = generate(params, cfg, spec, prompt, tables)
    calls = int(stats["calls"][0])
    tokens = int(stats["tokens"][0])
    assert tokens == 20
    assert 1 <= calls <= 20
    assert int(stats["accept_hist"][0].sum()) == calls
