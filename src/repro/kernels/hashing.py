"""The ONE definition of the context-N-gram continuation hash.

Every component that fingerprints a w-token continuation — the Pallas match
kernel (`ngram_match.py`), its pure-jnp oracle (`ref.py`) and the XLA
drafter sweep (`core/drafters.py`) — must agree bit-for-bit on this hash, or
the (count, recency) scoring stage would see different buckets per backend
and the backend-parity guarantee (drafts identical under ``backend="xla"``
and ``backend="pallas"``) would silently break.  They therefore all import
the constants and the step function from here instead of redeclaring them.

The hash is a Knuth-style multiplicative polynomial over uint32:

    h_0 = 0;  h_{j+1} = (h_j ^ (tok_j * HASH_MULT)) * HASH_MIX + 1

Collisions are possible but *harmless* for correctness: a collision only
merges the occurrence counts of two different continuations; verification
rejects any wrong token, so output still equals greedy decoding bit-for-bit.
"""
from __future__ import annotations

import jax.numpy as jnp

HASH_MULT = 2654435761        # Knuth multiplicative hash
HASH_MIX = 0x9E3779B9         # golden-ratio odd constant


def hash_step(h: jnp.ndarray, tok: jnp.ndarray) -> jnp.ndarray:
    """One token folded into the running hash. h: uint32; tok: any int."""
    return (h ^ (tok.astype(jnp.uint32) * jnp.uint32(HASH_MULT))) \
        * jnp.uint32(HASH_MIX) + 1


def hash_rows(rows: jnp.ndarray) -> jnp.ndarray:
    """Hash over the last axis of ``rows`` (..., w) -> (...) uint32."""
    h = jnp.zeros(rows.shape[:-1], jnp.uint32)
    for j in range(rows.shape[-1]):
        h = hash_step(h, rows[..., j])
    return h
