"""repro-lint (src/repro/analysis): every rule fires on its planted
violation, and the live codebase is clean modulo baseline/waivers.

Structure mirrors the subsystem: AST rules are exercised on synthetic
sources through ``analyze_source`` (so waiver plumbing is on the path),
jaxpr rules on planted functions/states through the same helpers the
live checks use, and one end-to-end run asserts the zero-findings gate
the CI lint lane enforces.
"""
import dataclasses
import json
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import Baseline, run_all
from repro.analysis import ast_rules as ar
from repro.analysis import jaxpr_rules as jr
from repro.analysis import registry
from repro.analysis.__main__ import main as lint_main
from repro.analysis.findings import Finding, apply_waivers, scan_waivers
from repro.distributed import sharding as shd


def _ast(relpath, source):
    findings, _ = ar.analyze_source(relpath, textwrap.dedent(source))
    return [f for f in findings if not f.waived]


# ---------------------------------------------------------------------------
# AST rules: planted violations + the matching clean variants
# ---------------------------------------------------------------------------
def test_pallas_scope_fires_outside_kernels():
    src = "import jax.experimental.pallas as pl\nout = pl.pallas_call(kern)(x)\n"
    got = _ast("core/rogue.py", src)
    assert [f.rule for f in got] == ["pallas-scope"]
    assert got[0].line == 2 and "pallas_call" in got[0].context


def test_pallas_scope_allowed_inside_kernels():
    src = "import jax.experimental.pallas as pl\nout = pl.pallas_call(kern)(x)\n"
    assert _ast("kernels/attn.py", src) == []


def test_tracer_branch_fires_on_traced_if():
    src = """
    import jax.numpy as jnp
    def f(x):
        y = jnp.sum(x)
        z = y + 1
        if z > 0:
            return 1
        while y:
            pass
    """
    got = _ast("core/rogue.py", src)
    assert sorted(f.rule for f in got) == ["tracer-branch", "tracer-branch"]


def test_tracer_branch_ignores_static_branches():
    src = """
    import jax.numpy as jnp
    def f(x, flag):
        y = jnp.sum(x)
        if x.shape[0] > 1:      # static: shapes are Python ints
            pass
        if flag:                # untraced argument
            pass
        return y
    """
    assert _ast("core/ok.py", src) == []


def test_tracer_branch_scoped_to_core():
    src = "import jax.numpy as jnp\ndef f(x):\n    y = jnp.sum(x)\n    if y > 0:\n        pass\n"
    assert _ast("serving/elsewhere.py", src) == []


def test_hash_constants_fires_on_rederivation():
    got = _ast("core/rogue.py", "MULT = 2654435761\nMIX = 0x9E3779B9\n")
    assert [f.rule for f in got] == ["hash-constants", "hash-constants"]


def test_hash_constants_fires_on_name_redefinition():
    got = _ast("core/rogue.py", "HASH_MULT = 12345\n")
    assert [f.rule for f in got] == ["hash-constants"]


def test_hash_constants_allowed_in_hashing_module():
    assert _ast("kernels/hashing.py", "HASH_MULT = 2654435761\n") == []


def test_global_state_fires_on_module_level_env_mutation():
    got = _ast("launch/rogue.py", "import os\nos.environ['XLA_FLAGS'] = '-x'\n")
    assert [f.rule for f in got] == ["global-state"]


def test_global_state_allows_main_guard_and_functions():
    src = """
    import os
    def setup():
        os.environ['XLA_FLAGS'] = '-x'    # runs when called, not at import
    if __name__ == "__main__":
        os.environ['XLA_FLAGS'] = '-x'    # entry-point pattern (dryrun)
    """
    assert _ast("launch/ok.py", src) == []


def test_global_state_fires_on_unpaired_install():
    src = "from repro.distributed import act_sharding\ndef go(mesh):\n    act_sharding.install(mesh)\n"
    got = _ast("serving/rogue.py", src)
    assert [f.rule for f in got] == ["global-state"]
    # pairing an uninstall in the module satisfies the rule
    assert _ast("serving/ok.py",
                src + "def stop():\n    act_sharding.uninstall()\n") == []


def test_time_in_jit_fires_in_jitted_and_body_fns():
    src = """
    import time, jax
    import numpy as np
    @jax.jit
    def f(x):
        t = time.time()
        return x
    def _step_body(s):
        r = np.random.rand()
        return s
    def host_fn():
        return time.time()       # fine: not a jitted body
    """
    got = _ast("core/rogue.py", src)
    assert sorted(f.rule for f in got) == ["time-in-jit", "time-in-jit"]


def test_serving_sync_rule_and_inventory():
    src = textwrap.dedent("""
    import numpy as np
    class Engine:
        def step(self):
            done = np.asarray(self.state.done)
            # repro-lint: allow(host-sync): test waiver
            ok = np.asarray(self.state.buf)
        def helper(self):
            also = np.asarray(self.state.buf)    # not a critical-path method
    """)
    findings, inventory = ar.analyze_source("serving/engine.py", src)
    sync = [f for f in findings if f.rule == "host-sync"]
    assert len(sync) == 2                       # helper() not scanned
    assert [f.waived for f in sync] == [False, True]
    # the inventory keeps waived entries — the async work needs the full map
    assert len(inventory) == 2
    assert inventory[1]["waived"] and inventory[1]["reason"] == "test waiver"


# ---------------------------------------------------------------------------
# waiver / baseline plumbing
# ---------------------------------------------------------------------------
def test_waiver_comment_applies_to_line_below():
    w = scan_waivers("x = 1\n# repro-lint: allow(a-rule): why\ny = 2\n")
    assert 2 in w and 3 in w and w[3] == ({"a-rule"}, "why")
    f = Finding(rule="a-rule", file="f.py", line=3, message="m")
    assert apply_waivers([f], w)[0].waived
    other = Finding(rule="other", file="f.py", line=3, message="m")
    assert not apply_waivers([other], w)[0].waived


def test_baseline_split_and_covers(tmp_path):
    f1 = Finding(rule="r", file="a.py", line=3, message="m", context="ctx")
    f2 = Finding(rule="r", file="a.py", line=9, message="m", context="new")
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps(
        {"entries": [{"rule": "r", "file": "a.py", "context": "ctx"}]}))
    b = Baseline.load(str(p))
    new, accepted = b.split([f1, f2])
    assert accepted == [f1] and new == [f2]
    # context matching survives line drift by construction (no line in key)
    assert b.covers(dataclasses.replace(f1, line=99))


# ---------------------------------------------------------------------------
# jaxpr rules: planted violations
# ---------------------------------------------------------------------------
def test_donation_fires_on_unusable_donation():
    # sum() shrinks the aval: the donated (8,) input matches no output
    struct = jax.ShapeDtypeStruct((8,), jnp.float32)
    got = jr.donation_findings(lambda x: x.sum(), (struct,), struct, "<p>")
    assert got and all(f.rule == "donation" for f in got)


def test_donation_clean_on_in_place_update():
    struct = {"a": jax.ShapeDtypeStruct((8,), jnp.float32)}
    fn = lambda s: {"a": s["a"] + 1}
    assert jr.donation_findings(fn, (struct,), struct, "<p>") == []


def test_shared_buffer_fires():
    z = jnp.zeros((4,), jnp.float32)            # same buffer, two leaves
    got = jr.shared_buffer_findings({"a": z, "b": z}, "<p>")
    assert len(got) == 1 and "share one device buffer" in got[0].message


def test_shared_buffer_clean_on_distinct_buffers():
    tree = {"a": jnp.zeros((4,)), "b": jnp.zeros((4,))}
    assert jr.shared_buffer_findings(tree, "<p>") == []


def test_signature_fires_on_aval_drift():
    struct = {"x": jax.ShapeDtypeStruct((4,), jnp.int32)}
    got = jr.signature_findings(lambda s: {"x": s["x"][:2]}, struct, "<p>")
    assert len(got) == 1 and "drifts" in got[0].message


def test_signature_fires_on_structure_drift():
    struct = {"x": jax.ShapeDtypeStruct((4,), jnp.int32)}
    got = jr.signature_findings(
        lambda s: {"x": s["x"], "extra": s["x"]}, struct, "<p>")
    assert len(got) == 1 and "only in the output" in got[0].message


def test_signature_clean_on_fixed_point():
    struct = {"x": jax.ShapeDtypeStruct((4,), jnp.int32)}
    assert jr.signature_findings(lambda s: {"x": s["x"] + 1}, struct,
                                 "<p>") == []


def test_host_sync_fires_on_debug_callback():
    def g(x):
        jax.debug.print("x={x}", x=x)
        return x + 1
    got = jr.jaxpr_sync_findings(g, (jnp.ones(3),), "<p>")
    assert len(got) == 1 and "debug_callback" in got[0].context


def test_host_sync_walks_nested_jaxprs():
    def g(x):
        def body(_, c):
            jax.debug.print("c={c}", c=c)
            return c + 1
        return jax.lax.fori_loop(0, 3, body, x)
    got = jr.jaxpr_sync_findings(g, (jnp.float32(0.0),), "<p>")
    assert got, "callback hidden inside a fori_loop body must be found"


# ---------------------------------------------------------------------------
# sharding coverage + the strict pspec contract (satellite)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def built_linear():
    return registry.build_case(registry.CASES[0])


def test_sharding_coverage_fires_on_ruleless_leaf(built_linear):
    st = built_linear.state
    st2 = dataclasses.replace(
        st, model={**st.model, "mystery": jnp.zeros((4, 4), jnp.float32)})
    b2 = dataclasses.replace(built_linear, state=st2)
    got = jr.check_sharding_coverage(b2)
    assert got and all("mystery" in f.message for f in got)
    assert len(got) == len(registry.MESHES)      # raised on every mesh


def test_strict_pspec_raises_on_unknown_leaf():
    mesh = registry.MESHES[0]
    path = (jax.tree_util.DictKey("model"), jax.tree_util.DictKey("mystery"))
    leaf = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    with pytest.raises(KeyError, match="DECODE_STATE_LEAF_RULES"):
        shd.decode_state_pspec(mesh, path, leaf, strict=True)
    # non-strict keeps the engine's replicate-unknown behaviour
    spec = shd.decode_state_pspec(mesh, path, leaf, strict=False)
    assert tuple(spec) == (None, None)


def test_leaf_rules_table_covers_every_registry_state():
    """The satellite contract: DECODE_STATE_LEAF_RULES is the single
    source of truth, and every leaf the engine actually builds (all
    registry cases, paged included) matches an entry."""
    for case in registry.CASES:
        built = registry.build_case(case)
        flat = jax.tree_util.tree_flatten_with_path(built.state)[0]
        for path, _ in flat:
            names = shd._path_names(path)
            assert (names[0] in shd.DECODE_STATE_LEAF_RULES
                    or names[-1] in shd.DECODE_STATE_LEAF_RULES), names


# ---------------------------------------------------------------------------
# end to end: the live codebase is clean, and the CLI gates on it
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_live_codebase_clean_modulo_baseline():
    findings, inventory = run_all()              # both levels, full registry
    baseline = Baseline.load(
        __import__("repro.analysis", fromlist=["DEFAULT_BASELINE"]
                   ).DEFAULT_BASELINE)
    new, _ = baseline.split(findings)
    assert new == [], "new findings:\n" + "\n".join(f.format() for f in new)
    # the engine's one structural sync (the retire done-flag readback) must
    # stay in the inventory — the async PR diffs against this map
    assert any(e["method"] == "_retire_finished" for e in inventory)


def test_cli_level2_strict_and_syncmap(tmp_path):
    out = tmp_path / "BENCH_syncmap.json"
    rc = lint_main(["--level", "2", "--strict", "--syncmap", str(out)])
    assert rc == 0
    data = json.loads(out.read_text())
    assert data["total"] == len(data["inventory"]) >= 1
    assert data["waived"] >= 1                   # engine waivers are mapped


def test_cli_fails_on_stale_baseline_only_when_strict(tmp_path):
    stale = tmp_path / "baseline.json"
    stale.write_text(json.dumps({"entries": [
        {"rule": "ghost", "file": "gone.py", "context": "x"}]}))
    assert lint_main(["--level", "2", "--baseline", str(stale)]) == 0
    assert lint_main(["--level", "2", "--strict",
                      "--baseline", str(stale)]) == 1
