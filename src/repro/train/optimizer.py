"""AdamW + schedules in pure JAX (no optax dependency).

Optimizer state is a pytree mirroring the params (m, v moments in f32) plus
a step counter; everything shards exactly like the params under pjit (the
moments inherit the param PartitionSpecs in distributed/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_ratio: float = 0.1


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = cfg.lr * jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1
                                                             + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params: Any) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any,
                 opt_state: Dict[str, Any]) -> Tuple[Any, Dict[str, Any],
                                                     Dict[str, jnp.ndarray]]:
    step = opt_state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** (step.astype(jnp.float32) + 1)
    bc2 = 1 - b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m,
                                                 flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step + 1}, metrics
