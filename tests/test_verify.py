"""Acceptance-rule unit tests (paper §4.1 batched guess-and-verify)."""
import jax.numpy as jnp
import numpy as np

from repro.core.verify import accept


def test_accept_basic():
    # k=2, w=3. Row 0 matches 2 drafts, row 1 matches 0.
    drafts = jnp.asarray([[[5, 6, 7], [9, 9, 9]]])
    greedy = jnp.asarray([[[5, 6, 8, 4], [5, 1, 2, 3]]])
    a = accept(drafts, greedy)
    assert int(a.winner[0]) == 0
    assert int(a.n_commit[0]) == 3           # 2 accepted + bonus
    np.testing.assert_array_equal(np.asarray(a.tokens[0, :3]), [5, 6, 8])


def test_accept_no_match_gives_bonus():
    drafts = jnp.asarray([[[3, 3], [4, 4]]])
    greedy = jnp.asarray([[[7, 1, 2], [7, 5, 6]]])
    a = accept(drafts, greedy)
    assert int(a.n_commit[0]) == 1
    assert int(a.tokens[0, 0]) == 7          # the model's own next token


def test_accept_full_match():
    drafts = jnp.asarray([[[1, 2, 3]]])
    greedy = jnp.asarray([[[1, 2, 3, 4]]])
    a = accept(drafts, greedy)
    assert int(a.n_commit[0]) == 4
    np.testing.assert_array_equal(np.asarray(a.tokens[0]), [1, 2, 3, 4])


def test_accept_tie_prefers_lower_row():
    """Ties -> first row (context drafts sit first under the mixed strategy)."""
    drafts = jnp.asarray([[[1, 9], [1, 8]]])
    greedy = jnp.asarray([[[1, 5, 0], [1, 5, 0]]])
    a = accept(drafts, greedy)
    assert int(a.winner[0]) == 0
    assert int(a.n_commit[0]) == 2
    np.testing.assert_array_equal(np.asarray(a.tokens[0, :2]), [1, 5])


def test_accept_interior_restart_not_counted():
    """A draft matching again AFTER a mismatch must not count (prefix only)."""
    drafts = jnp.asarray([[[1, 9, 3]]])
    greedy = jnp.asarray([[[1, 2, 3, 4]]])
    a = accept(drafts, greedy)
    assert int(a.n_commit[0]) == 2           # 1 accepted + bonus(2)
    np.testing.assert_array_equal(np.asarray(a.tokens[0, :2]), [1, 2])
