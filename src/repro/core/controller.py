"""Adaptive (k, w) controller — beyond-paper extension.

The paper sweeps a static (k, w) grid offline and notes (§5.2) that smarter
strategy allocation "could yield further gains".  This controller picks the
strategy ONLINE from a small set of arms:

    score(arm) = EMA_tokens_per_call(arm) / roofline_slowdown(arm | ell)

i.e. measured acceptance divided by the modeled call-time inflation
(core/phase.py), with a UCB exploration bonus.  Arms are a fixed list so the
jitted engine never recompiles outside the precompiled set (a TPU serving
requirement).

Two implementations share the scoring rule:

  - ``AdaptiveKW`` — the host-side bandit: one arm per whole *batch*
    (serve_all picks before launching a monolithic ``generate``).
  - the vectorized per-slot bandit (``init_arm_stats`` / ``choose_arms`` /
    ``update_arm_stats``) — pure jnp ops over (B, A) stat arrays that live
    inside ``DecodeState.stats`` and run *inside* the jitted ``spec_step``
    (DESIGN.md §9).  Every slot keeps its own counts/rewards, so a
    continuous-batching engine adapts per request in flight; admission and
    release zero a slot's rows, so a reused slot starts exploring afresh.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from ..models.config import ModelConfig
from .phase import slowdown


@dataclasses.dataclass
class ArmStats:
    tokens: float = 0.0
    calls: float = 0.0
    pulls: int = 0

    @property
    def tpc(self) -> float:
        return self.tokens / self.calls if self.calls else 1.0


DEFAULT_ARMS: Tuple[Tuple[int, int], ...] = ((1, 0), (5, 4), (10, 4),
                                             (10, 10), (25, 2))


class AdaptiveKW:
    def __init__(self, cfg: ModelConfig,
                 arms: Tuple[Tuple[int, int], ...] = DEFAULT_ARMS,
                 ell: int = 512, ema: float = 0.9,
                 explore: float = 0.3):
        self.cfg = cfg
        self.arms: List[Tuple[int, int]] = list(arms)
        self.ell = ell
        self.ema = ema
        self.explore = explore
        self.stats: Dict[Tuple[int, int], ArmStats] = {
            a: ArmStats() for a in self.arms}
        # modeled call slowdown per arm (the roofline prior)
        self.slow: Dict[Tuple[int, int], float] = {
            (k, w): slowdown(cfg, ell, k, w) if (k, w) != (1, 0) else 1.0
            for (k, w) in self.arms}
        self.total_pulls = 0

    def score(self, arm: Tuple[int, int]) -> float:
        s = self.stats[arm]
        # optimistic prior before any pull: assume half the draft accepted
        tpc = s.tpc if s.pulls else 1.0 + arm[1] * 0.5
        bonus = self.explore * math.sqrt(
            math.log(self.total_pulls + 1) / (s.pulls + 1e-9)) \
            if s.pulls else float("inf")
        return tpc / self.slow[arm] + bonus

    def choose(self) -> Tuple[int, int]:
        return max(self.arms, key=self.score)

    def update(self, arm: Tuple[int, int], tokens: float,
               calls: float) -> None:
        s = self.stats[arm]
        if s.pulls:
            s.tokens = self.ema * s.tokens + (1 - self.ema) * tokens
            s.calls = self.ema * s.calls + (1 - self.ema) * calls
        else:
            s.tokens, s.calls = tokens, calls
        s.pulls += 1
        self.total_pulls += 1

    def best_exploit(self) -> Tuple[int, int]:
        """Current best arm ignoring exploration bonus."""
        return max(self.arms,
                   key=lambda a: (self.stats[a].tpc if self.stats[a].pulls
                                  else 0.0) / self.slow[a])


# ---------------------------------------------------------------------------
# vectorized per-slot bandit (runs INSIDE the jitted spec_step)
# ---------------------------------------------------------------------------
# One pull == one verify call of one slot, rewarded with the tokens that
# call committed (n_commit, bonus included) — the per-call analogue of
# AdaptiveKW's whole-batch tokens/calls EMA.  All state is (B, A)-shaped
# arrays keyed into DecodeState.stats, so it is donated, slot-resettable
# with the rest of the per-slot stats, and needs no host round-trip.
ARM_STAT_KEYS = ("arm_pulls", "arm_reward", "arm_last")

# scores are f32; any finite exploit score is < _UNPULLED, so unpulled arms
# are explored first in index order (AdaptiveKW's infinite-bonus behaviour)
_UNPULLED = 1e30


def init_arm_stats(num_slots: int, num_arms: int) -> Dict[str, jnp.ndarray]:
    """Fresh per-slot bandit state: zero pulls/rewards for every arm."""
    return {
        "arm_pulls": jnp.zeros((num_slots, num_arms), jnp.int32),
        "arm_reward": jnp.zeros((num_slots, num_arms), jnp.float32),
        "arm_last": jnp.zeros((num_slots,), jnp.int32),
    }


def arm_slowdowns(cfg: ModelConfig, arms: Tuple[Tuple[int, int], ...],
                  ell: int = 512) -> Tuple[float, ...]:
    """Roofline call-slowdown prior per arm (the denominator of the score).

    Host-side floats computed from static shapes, so they fold into the jit
    as constants — no recompilation across steps or arm switches.
    """
    return tuple(slowdown(cfg, ell, k, w) if (k, w) != (1, 0) else 1.0
                 for (k, w) in arms)


def tree_arm_slowdowns(cfg: ModelConfig,
                       arms: Tuple[Tuple[int, int], ...],
                       branch: int, ell: int = 512) -> Tuple[float, ...]:
    """Roofline prior for TREE arms (DESIGN.md §11).

    A (width, depth) tree arm verifies num_nodes(width, depth, branch) + 1
    tokens as ONE row, so its call cost is modeled as a single row of that
    many positions — slowdown(cfg, ell, 1, N) — not as width independent
    rows.  Depth-0 arms verify only the root (plain greedy): 1.0.
    """
    from .tree import num_nodes
    return tuple(
        slowdown(cfg, ell, 1, num_nodes(k, w, branch)) if w > 0 else 1.0
        for (k, w) in arms)


def choose_arms(stats: Dict[str, jnp.ndarray],
                slowdowns: Tuple[float, ...],
                explore: float = 0.3) -> jnp.ndarray:
    """UCB arm per slot from (B, A) stats; ties break to the lowest index.

    score = EMA_tokens_per_call / slowdown + explore * sqrt(log(T)/pulls),
    with never-pulled arms forced first in index order (the vectorized
    rendering of AdaptiveKW's infinite exploration bonus).  Rows are fully
    independent: slot b's choice reads only stats[b].
    """
    pulls = stats["arm_pulls"]                              # (B, A) int32
    pulled = pulls > 0
    total = pulls.sum(axis=1, keepdims=True)                # per-slot T
    bonus = explore * jnp.sqrt(
        jnp.log(total.astype(jnp.float32) + 1.0)
        / jnp.maximum(pulls.astype(jnp.float32), 1.0))
    slow = jnp.asarray(slowdowns, jnp.float32)[None, :]
    score = jnp.where(pulled, stats["arm_reward"] / slow + bonus,
                      _UNPULLED)
    return jnp.argmax(score, axis=1).astype(jnp.int32)


def update_arm_stats(stats: Dict[str, jnp.ndarray], arm: jnp.ndarray,
                     reward: jnp.ndarray, active: jnp.ndarray,
                     ema: float = 0.9) -> Dict[str, jnp.ndarray]:
    """Record one pull of ``arm[b]`` with ``reward[b]`` tokens for every
    active slot (inactive rows are untouched, like the per-slot call/token
    stats).  First pull seeds the EMA with the raw reward (AdaptiveKW)."""
    A = stats["arm_pulls"].shape[1]
    sel = (jnp.arange(A)[None, :] == arm[:, None]) & active[:, None]
    first = stats["arm_pulls"] == 0
    reward = reward.astype(jnp.float32)[:, None]
    blended = jnp.where(first, reward,
                        ema * stats["arm_reward"] + (1.0 - ema) * reward)
    return {**stats,
            "arm_pulls": stats["arm_pulls"] + sel.astype(jnp.int32),
            "arm_reward": jnp.where(sel, blended, stats["arm_reward"]),
            "arm_last": jnp.where(active, arm, stats["arm_last"])}
