"""Serving engine: ties the scheduler to the jitted speculative generator.

One ``ServingEngine`` owns (params, cfg, tables) and serves batched requests
with either plain greedy decoding or the paper's batched speculation —
switching is one constructor argument, which is the paper's P3
('plug-and-play', no model modification).

Two serving modes share the engine:

  - ``serve_all``     — static batching: the scheduler forms whole batches
    and each runs one monolithic jitted ``generate``; a finished row idles
    its slot until the slowest row of its batch completes.
  - ``serve_continuous`` / ``step`` — continuous batching over the reusable
    jitted ``spec_step``: between verify calls, finished rows are retired
    and queued prompts are prefilled into the freed slots (admit_slot), so
    slots never idle while there is work queued.

``adaptive=True`` works in BOTH modes, with different machinery: serve_all
picks one (k, w) arm per whole batch with the host-side UCB controller
(core/controller.py AdaptiveKW); continuous batching instead bakes the arm
table into the spec_step as shape-stable masking (SpecConfig.arms,
DESIGN.md §9) — every slot picks its own arm every step INSIDE the jit, so
one compilation serves every arm and requests adapt individually while in
flight.

Continuous batching can further run over the PAGED KV layout
(``paged=True``, DESIGN.md §8): slots share a page pool with per-slot page
tables and admission is gated on pages-available (worst-case reservation,
deferral when the pool is exhausted) instead of slot count alone —
bit-identical outputs, but one long-context request no longer forces every
slot to a worst-case linear buffer.

Both modes also serve SHARDED over a real ``jax.sharding.Mesh``
(``mesh=...``, DESIGN.md §10): params/DecodeState get NamedShardings from
``distributed/sharding``, the step/admit/release jits are rebuilt with
those shardings pinned on inputs and outputs (donation + single-trace
preserved), and the activation sharder is scoped to this engine's traces —
never installed globally.  Outputs remain bit-identical to unsharded
serving; ``mesh_report()`` shows what actually sharded.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
import warnings
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..core.ngram_tables import NGramTables, build_bigram, build_unigram
from ..core.spec_engine import (DecodeState, PagedConfig, SpecConfig,
                                admit_slot, empty_decode_state, generate,
                                make_sharded_slot_fns, release_slot,
                                spec_step)
from ..data.tokenizer import ByteTokenizer
from ..distributed import act_sharding
from ..distributed import sharding as shd
from ..kernels import dispatch
from ..models import cache as Cache
from ..models import model as M
from ..models.config import ModelConfig
from .scheduler import DEFAULT_BUCKETS, Batch, Request, Scheduler, SlotMap


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig,
                 spec: Optional[SpecConfig] = None,
                 tables: Optional[NGramTables] = None,
                 max_batch: int = 8,
                 adaptive: bool = False,
                 arms: Optional[Tuple[Tuple[int, int], ...]] = None,
                 buckets: Optional[Tuple[int, ...]] = None,
                 max_new_cap: int = 64,
                 bucket_align: Optional[int] = None,
                 paged: bool = False,
                 num_pages: Optional[int] = None,
                 page_size: int = 0,
                 mesh: Optional[Mesh] = None,
                 sampling: Optional[bool] = None,
                 seed: int = 0):
        """``adaptive``: pick (k, w) online with the UCB controller
        (core/controller.py, beyond-paper) instead of a static setting —
        per whole batch under serve_all, per slot per step (shape-stable
        arm masking inside the jitted spec_step) under continuous batching.
        ``arms`` overrides the controller's arm table (DEFAULT_ARMS).
        ``buckets``/``max_new_cap`` bound the continuous-batching DecodeState
        (buffer length = largest bucket + max_new_cap + w + 2).
        ``bucket_align``: bucket-boundary multiple; None = lane-aligned when
        the Pallas backend is active, else 1 (kernels/dispatch.py).

        ``paged``: continuous batching over the paged KV layout (DESIGN.md
        §8): slots share a ``num_pages``-page pool (default: the linear
        worst case — pass less to actually cap memory) and admission is
        page-reservation-based, so one long-context request no longer
        forces every slot to a worst-case linear buffer.  ``page_size`` 0
        follows cfg.kernel_block_s (the Pallas verify kernel's cache
        block).  Bit-identical outputs to the linear layout.

        ``mesh``: serve SHARDED over a ``jax.sharding.Mesh`` (DESIGN.md
        §10): params are placed by ``distributed.sharding.params_shardings``,
        the continuous DecodeState by ``decode_state_shardings``, and the
        jitted step/admit/release are rebuilt with those shardings pinned on
        inputs AND outputs (donation + the single-trace guarantee survive —
        see spec_engine.make_sharded_slot_fns).  The engine OWNS the
        activation sharder: it is active only inside this engine's traces
        (act_sharding.activated), never installed globally, so other
        engines in the process keep their own backend eligibility.
        Outputs are bit-identical to the same engine without a mesh.
        Known seam: a mesh pins ``attn_verify`` to the sharded XLA
        flash-decode path — the Pallas verify kernel is single-device today
        (models/attention.py:_use_verify_kernel), so ``backend="pallas"``
        is ignored (with a warning) under a mesh.

        ``sampling``: compile the lossless sampled verification walk into
        the continuous spec_step (DESIGN.md §12) so temperature > 0
        requests serve speculatively.  None (default) auto-resolves when
        the continuous state is built: sampling is enabled iff a sampled
        request is queued (or spec.sampling was set).  Pass True to
        pre-commit (e.g. when sampled traffic arrives after the first
        step), False to pin the greedy-only executable — sampled requests
        are then rejected at admission instead of silently served greedy.
        ``seed`` is the engine's base rng key; request keys derive as
        fold_in(seed_key, request_id) unless the request pins its own
        ``seed`` — both replayable.  serve_all resolves sampling per batch
        (static batching recompiles per batch shape anyway).  Mesh seam:
        temperature-0 rows stay bit-exact vs unsharded serving, but
        SAMPLED rows are bit-reproducible only per mesh configuration —
        sharded matmul reductions perturb logits at the ~1e-6 level, which
        argmax absorbs but a gumbel-argmax draw near its (dense) decision
        boundary does not.  The output distribution is unchanged to the
        same ~1e-6."""
        self.params = params
        self.cfg = cfg
        self.spec = spec or SpecConfig(strategy="greedy")
        if self.spec.tree:
            self.spec.validate_tree()
            if M.has_recurrent(cfg):
                raise ValueError(
                    f"{cfg.name}: tree speculation needs an attention-only "
                    f"arch — recurrent mixers verify rows as causal "
                    f"sequences, which has no valid tree layout "
                    f"(DESIGN.md §11)")
        self.tok = ByteTokenizer()
        self.max_batch = max_batch
        self.max_new_cap = max_new_cap
        self.mesh = mesh
        # sampling=None resolves lazily in _init_continuous (queued sampled
        # request -> True); spec.sampling=True is an explicit pre-commit
        self.sampling = (True if self.spec.sampling else sampling)
        self.seed = seed
        self._seed_key = jax.random.PRNGKey(seed)
        self._explicit_buckets = buckets is not None
        if mesh is not None:
            if (dispatch.use_pallas(cfg.backend)
                    and dispatch.pallas_verify_supported(cfg)) \
                    or dispatch.use_pallas(self.spec.backend):
                warnings.warn(
                    f"{cfg.name}: mesh serving pins the Pallas kernels to "
                    f"their XLA paths (attn_verify -> sharded flash-decode, "
                    f"drafter sweep -> XLA ref) — the kernels are "
                    f"single-device today (kernel-dispatch seam, "
                    f"DESIGN.md §10)")
            self.params = jax.device_put(
                params, shd.params_shardings(mesh, params))
        # when the verify kernel is live, size every static length (bucket
        # ladder, continuous DecodeState buffer) to kernel-friendly
        # multiples so spec_attention_op never repads the cache per step
        # (moot under a mesh: the XLA path is pinned there)
        self._kernel_aligned = (
            mesh is None
            and dispatch.use_pallas(cfg.backend)
            and dispatch.pallas_verify_supported(cfg))
        if bucket_align is None:
            bucket_align = dispatch.LANE if self._kernel_aligned else 1
        self.scheduler = Scheduler(
            max_batch=max_batch,
            buckets=buckets if buckets is not None else DEFAULT_BUCKETS,
            align=bucket_align)
        self.controller = None
        self._arms: Optional[Tuple[Tuple[int, int], ...]] = None
        if adaptive:
            from ..core.controller import DEFAULT_ARMS, AdaptiveKW
            self._arms = tuple(tuple(a) for a in (arms or DEFAULT_ARMS))
            self.controller = AdaptiveKW(cfg, arms=self._arms)
        elif arms is not None:
            raise ValueError("arms= requires adaptive=True")
        self.paged = paged
        if paged and not Cache.paged_supported(cfg):
            raise ValueError(
                f"{cfg.name}: paged KV needs a linear-cache attention arch "
                f"(sliding_window=None, >=1 attn layer); run linear instead")
        self._paged_cfg = (PagedConfig(num_pages or 0, page_size)
                           if paged else None)
        if (self.spec.strategy != "greedy" or adaptive) and tables is None:
            arm_k = max((a[0] for a in self._arms or ()), default=0)
            arm_w = max((a[1] for a in self._arms or ()), default=0)
            tables = self.build_tables(k_max=max(self.spec.k, 25, arm_k),
                                       w_max=max(self.spec.w, 16, arm_w))
        self.tables = tables
        if mesh is not None and self.tables is not None:
            # draft tables are small integer lookups: replicate them
            self.tables = jax.device_put(
                self.tables, jax.tree_util.tree_map(
                    lambda _: shd.replicated(mesh), self.tables))
        self._gen_cache: Dict = {}
        # continuous-batching state, built lazily on first step();
        # _cont_spec is the spec the continuous path actually runs —
        # adaptive mode rebuilds it around the arm table in _init_continuous
        self._cont_spec: SpecConfig = self.spec
        self._cont_state: Optional[DecodeState] = None
        self._slots: Optional[SlotMap] = None

    # ------------------------------------------------------------------
    def _act(self):
        """Scoped activation sharder: the engine's mesh is active only
        inside its own traces and always uninstalled on exit — the
        mesh-state-hygiene contract (a meshed engine must not pin OTHER
        engines off the Pallas path)."""
        return (act_sharding.activated(self.mesh) if self.mesh is not None
                else contextlib.nullcontext())

    def build_tables(self, k_max: int = 16, w_max: int = 16,
                     batch: int = 256) -> NGramTables:
        """One-off model sweep (paper: <1 min for a 7B on one A100)."""
        fwd = jax.jit(lambda t: M.forward(self.params, self.cfg,
                                          tokens=t)[0][:, -1])
        with self._act():
            topk, chain = build_bigram(fwd, self.cfg.vocab_size, k_max=k_max,
                                       w_max=w_max, batch=batch)
        uni = build_unigram(self.params["embed"]["embedding"],
                            self.params["embed"].get(
                                "lm_head",
                                self.params["embed"]["embedding"].T),
                            k_max=k_max)
        return NGramTables(unigram_topk=uni, bigram_topk=topk,
                           bigram_chain=chain)

    # ------------------------------------------------------------------
    def submit(self, prompt: str, max_new_tokens: int = 64,
               eos_id: int = -1, temperature: float = 0.0,
               top_p: float = 1.0, seed: Optional[int] = None) -> Request:
        """Queue a request.  ``temperature`` 0 decodes greedy (bit-exact
        spec path); > 0 samples losslessly through the same spec_step
        (DESIGN.md §12) with nucleus mass ``top_p``.  ``seed`` pins the
        request's rng key (None: derived from the engine seed and
        request_id — deterministic either way)."""
        if temperature < 0:
            raise ValueError(
                f"temperature must be >= 0, got {temperature} (pass 0 for "
                f"greedy decoding; negative values are always a bug)")
        if not 0.0 < top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {top_p}")
        req = Request(prompt=prompt, max_new_tokens=max_new_tokens,
                      eos_id=eos_id, temperature=temperature, top_p=top_p,
                      seed=seed)
        self.scheduler.submit(req)
        return req

    def _req_key(self, req: Request) -> jnp.ndarray:
        """The request's (2,) uint32 rng key: its own seed when pinned,
        else fold_in(engine seed key, request_id).  Pure function of
        (engine seed, request) — resubmitting the same request with the
        same seed replays the same sampled output, in any batch mix
        (slots are independent, so a request's trajectory never depends
        on its neighbours)."""
        if req.seed is not None:
            return jax.random.PRNGKey(req.seed)
        return jax.random.fold_in(self._seed_key, req.request_id)

    def _gen_fn(self, max_new: int, kw=None, sampled: bool = False):
        key = (max_new, kw, sampled)
        if key not in self._gen_cache:
            spec = dataclasses.replace(self.spec, max_new_tokens=max_new,
                                       sampling=sampled)
            if kw is not None:                      # adaptive controller arm
                k, w = kw
                strategy = ("greedy" if w == 0 else
                            ("mixed" if self.spec.strategy == "greedy"
                             else self.spec.strategy))
                # the w == 0 arm is plain greedy: there is no tree to build
                # (validate_tree rejects tree+greedy), so drop the flag
                spec = dataclasses.replace(spec, k=max(k, 1), w=max(w, 1),
                                           strategy=strategy,
                                           tree=spec.tree and w > 0)
            if sampled:
                # per-row controls become runtime args; greedy rows inside
                # the batch (temperature 0) stay bit-exact in the same trace
                self._gen_cache[key] = jax.jit(
                    lambda p, toks, eos, tbl, t, tp, ky: generate(
                        p, self.cfg, spec, toks, tbl, eos_id=eos,
                        temperature=t, top_p=tp, rng=ky))
            else:
                # greedy-only batches keep the pre-sampling signature (and
                # therefore the exact executable the seed engine compiled)
                self._gen_cache[key] = jax.jit(
                    lambda p, toks, eos, tbl: generate(p, self.cfg, spec,
                                                       toks, tbl,
                                                       eos_id=eos))
        return self._gen_cache[key]

    def _effective_eos(self, req: Request) -> int:
        """Per-request eos wins; fall back to the engine-wide spec.eos_id —
        the same resolution in both serving modes, so a given submission
        stops identically under serve_all and serve_continuous."""
        return req.eos_id if req.eos_id >= 0 else self.spec.eos_id

    def run_batch(self, batch: Batch) -> List[Request]:
        kw = self.controller.choose() if self.controller else None
        # static batching resolves sampling per batch: a batch with any
        # sampled request runs the sampled trace (its greedy rows stay
        # bit-exact), an all-greedy batch keeps the greedy-only executable
        sampled = (self.sampling is True
                   or any(r.temperature > 0 for r in batch.requests))
        fn = self._gen_fn(batch.max_new_tokens, kw, sampled)
        eos = jnp.asarray([self._effective_eos(r) for r in batch.requests],
                          jnp.int32)
        tokens = jnp.asarray(batch.tokens)
        sample_args = ()
        if sampled:
            sample_args = (
                jnp.asarray([r.temperature for r in batch.requests],
                            jnp.float32),
                jnp.asarray([r.top_p for r in batch.requests], jnp.float32),
                jnp.stack([self._req_key(r) for r in batch.requests]))
        if self.mesh is not None:
            tokens = jax.device_put(
                tokens, shd.batch_sharding(self.mesh, tokens.shape))
            eos = jax.device_put(eos, shd.batch_sharding(self.mesh,
                                                         eos.shape))
            sample_args = tuple(
                jax.device_put(a, shd.batch_sharding(self.mesh, a.shape))
                for a in sample_args)
        t0 = time.perf_counter()
        with self._act():
            buf, blen, stats = fn(self.params, tokens, eos, self.tables,
                                  *sample_args)
        buf.block_until_ready()
        dt = time.perf_counter() - t0
        if self.controller:
            self.controller.update(
                kw, tokens=float(np.asarray(stats["tokens"]).sum()),
                calls=float(max(np.asarray(stats["calls"]).sum(), 1)))
        P = batch.tokens.shape[1]
        buf = np.asarray(buf)
        blen = np.asarray(blen)
        for i, req in enumerate(batch.requests):
            req.output_ids = buf[i, P:blen[i]].copy()
            req.output = self.tok.decode(req.output_ids)
            req.stats = {
                "new_tokens": int(blen[i] - P),
                "model_calls": int(np.asarray(stats["calls"])[i]),
                "tokens_per_call": float(np.asarray(stats["tokens"])[i]
                                         / max(1, np.asarray(
                                             stats["calls"])[i])),
                "accept_hist": np.asarray(stats["accept_hist"])[i].tolist()
                if "accept_hist" in stats else [],
                "wall_time_s": dt,
            }
        return batch.requests

    def serve_all(self) -> List[Request]:
        done: List[Request] = []
        while True:
            batch = self.scheduler.next_batch()
            if batch is None:
                return done
            done.extend(self.run_batch(batch))

    # ------------------------------------------------------------------
    # continuous batching (slot-level admission / retirement)
    # ------------------------------------------------------------------
    def _init_continuous(self) -> None:
        # adaptive continuous: bake the controller's arm table into the
        # spec as shape-stable masking (DESIGN.md §9) — the step's shapes
        # are the arm-table maxima, every slot picks its arm per step
        # inside the ONE jitted spec_step, and the per-slot bandit state
        # rides in DecodeState.stats (zeroed on slot admission/release)
        spec = self.spec
        if self.controller is not None:
            k_max = max(a[0] for a in self._arms)
            w_max = max(a[1] for a in self._arms)
            strategy = ("mixed" if spec.strategy == "greedy"
                        else spec.strategy)
            # spec.tree rides through the replace: tree arms read the same
            # (k, w) table as (width, depth) under path masking (§11)
            spec = dataclasses.replace(
                spec, k=k_max, w=max(w_max, 1), strategy=strategy,
                arms=self._arms).validate_arms().validate_tree()
        # resolve the static sampling flag ONCE, at state build time:
        # sampling=None enables the sampled walk iff a sampled request is
        # already queued.  The flag is compile-time (DESIGN.md §12), so a
        # sampled request reaching a greedy-only compiled step is rejected
        # at admission (_admit_queued) rather than recompiling the step or
        # silently serving it greedy.
        if self.sampling is None:
            self.sampling = any(r.temperature > 0
                                for r in self.scheduler.queued_requests())
        if self.sampling and not spec.sampling:
            spec = dataclasses.replace(spec, sampling=True)
        self._cont_spec = spec
        # size the DecodeState to the queued workload, not the 512-token
        # worst case; the scheduler itself is left untouched (a later
        # serve_all on this engine sees the full bucket ladder).  Prompts
        # longer than the sized capacity are REJECTED at admission with a
        # per-request error stat (truncating them would silently corrupt
        # the output).  Pass buckets= explicitly to reserve more up front.
        # Paged mode reserves the FULL bucket ladder instead: per-slot
        # token buffers are cheap (int32), and KV capacity is governed by
        # the page pool, not the per-slot buffer length.
        prompt_cap = self.scheduler.buckets[-1]
        if not self.paged and not self._explicit_buckets:
            prompt_cap = self.scheduler.max_queued_bucket() or prompt_cap
        self._cont_prompt_cap = prompt_cap
        buf_size = prompt_cap + self.max_new_cap + self._cont_spec.w + 2
        if self._kernel_aligned:
            buf_size = dispatch.align_cache_len(buf_size,
                                                self.cfg.kernel_block_s)
        self._cont_state = empty_decode_state(self.cfg, self._cont_spec,
                                              self.max_batch, buf_size,
                                              paged=self._paged_cfg)
        # mesh serving: place the state, then rebuild the three slot jits
        # with every in/out sharding pinned (donation + single-trace under
        # NamedSharding — spec_engine.make_sharded_slot_fns).  mesh=None
        # keeps the module-level jits, shared across engines.
        self._step_jit = self._admit_jit = self._release_jit = None
        self._step_hlo_text: Optional[str] = None
        if self.mesh is not None:
            self._state_shardings = shd.decode_state_shardings(
                self.mesh, self._cont_state)
            self._cont_state = jax.device_put(self._cont_state,
                                              self._state_shardings)
            params_sh = jax.tree_util.tree_map(lambda x: x.sharding,
                                               self.params)
            tables_sh = (jax.tree_util.tree_map(
                lambda _: shd.replicated(self.mesh), self.tables)
                if self.tables is not None else None)
            self._step_jit, self._admit_jit, self._release_jit = \
                make_sharded_slot_fns(self.cfg, self._cont_spec,
                                      params_sh=params_sh,
                                      state_sh=self._state_shardings,
                                      tables_sh=tables_sh,
                                      scalar_sh=shd.replicated(self.mesh))
        self._slots = SlotMap(self.max_batch)
        # host-side aggregate of retired requests' arm pulls (adaptive)
        self._arm_pulls_total = (np.zeros(len(self._arms), np.int64)
                                 if self._arms else None)
        # page accounting (paged mode): admission reserves each request's
        # worst-case page count up front so the in-step on-the-fly growth
        # (spec_engine) can never exhaust the pool mid-flight; physical
        # allocation stays lazy.  All host-side — no device sync to admit.
        if self.paged:
            self._page_size = self._paged_cfg.resolve_page_size(self.cfg)
            pps = self._cont_state.buf_size // self._page_size
            self._pool_pages = (self._paged_cfg.num_pages
                                or self.max_batch * pps)
            self._page_reserved: Dict[int, int] = {}
            self._pool_peak = 0
            self._deferrals = 0
        self._rejected = 0

    def in_flight(self) -> int:
        return len(self._slots) if self._slots is not None else 0

    # the three continuous-path device calls, routed through either the
    # module-level jits (mesh=None) or this engine's sharding-pinned jits
    def _run_step(self, state: DecodeState) -> DecodeState:
        with self._act():
            if self._step_jit is not None:
                return self._step_jit(self.params, state, self.tables)
            return spec_step(self.params, self.cfg, self._cont_spec, state,
                             self.tables)

    def _run_admit(self, state: DecodeState, slot: int, toks,
                   mnt: int, eos: int, req: Request) -> DecodeState:
        temp = jnp.float32(req.temperature)
        topp = jnp.float32(req.top_p)
        key = self._req_key(req)
        with self._act():
            if self._admit_jit is not None:
                return self._admit_jit(self.params, state, jnp.int32(slot),
                                       jnp.asarray(toks), jnp.int32(mnt),
                                       jnp.int32(eos), temp, topp, key)
            return admit_slot(self.params, self.cfg, state, jnp.int32(slot),
                              jnp.asarray(toks), jnp.int32(mnt),
                              jnp.int32(eos), temp, topp, key)

    def _run_release(self, state: DecodeState, slot: int) -> DecodeState:
        with self._act():
            if self._release_jit is not None:
                return self._release_jit(state, jnp.int32(slot))
            return release_slot(state, jnp.int32(slot))

    def _retire_finished(self) -> List[Request]:
        state = self._cont_state
        # The scheduler's one unavoidable per-step sync: slot reuse is a
        # host decision, so the done flags must come back every step.  The
        # ROADMAP's async-serving item replaces this with a lagged readback;
        # until then it is THE baseline entry in BENCH_syncmap.json.
        # repro-lint: allow(host-sync): scheduling branches on done flags host-side; async serving (ROADMAP) is the structural fix
        done = np.asarray(state.done)
        if not done[[s for s, _ in self._slots.occupied()]].any():
            return []
        if self.paged:
            # pool peak: occupancy only falls at release, so sampling here
            # (before this round's frees) sees every high-water mark
            # repro-lint: allow(host-sync): runs only on retire rounds, behind the done.any() gate — off the steady-state step path
            in_use = self._pool_pages - int(np.asarray(state.model["free_top"]))
            self._pool_peak = max(self._pool_peak, in_use)
        # one device->host transfer per array, not per retired slot, and
        # only on rounds that actually retire (behind the done.any() gate)
        blen = np.asarray(state.buf_len)        # repro-lint: allow(host-sync): batched retire-round readback
        plen = np.asarray(state.prompt_len)     # repro-lint: allow(host-sync): batched retire-round readback
        buf = np.asarray(state.buf)             # repro-lint: allow(host-sync): batched retire-round readback
        calls_np = np.asarray(state.stats["calls"])    # repro-lint: allow(host-sync): batched retire-round readback
        tokens_np = np.asarray(state.stats["tokens"])  # repro-lint: allow(host-sync): batched retire-round readback
        accept_hist_np = np.asarray(state.stats["accept_hist"])  # repro-lint: allow(host-sync): batched retire-round readback
        arm_pulls_np = (np.asarray(state.stats["arm_pulls"])  # repro-lint: allow(host-sync): batched retire-round readback
                        if self._arms else None)
        retired: List[Request] = []
        for slot, req in self._slots.occupied():
            if not done[slot]:
                continue
            calls = int(calls_np[slot])
            tokens = int(tokens_np[slot])
            req.output_ids = buf[slot, plen[slot]:blen[slot]].copy()
            req.output = self.tok.decode(req.output_ids)
            req.stats = {
                "new_tokens": int(blen[slot] - plen[slot]),
                "model_calls": calls,
                "tokens_per_call": float(tokens / max(1, calls)),
                # this request's acceptance-length histogram: entry n =
                # verify calls that committed exactly n tokens (0..w+1) —
                # the paper's Fig. 4 ablation, per request (read BEFORE
                # release zeroes the slot's stats rows)
                # repro-lint: allow(host-sync): numpy-side tolist on the already-transferred accept_hist_np, not a device sync
                "accept_hist": accept_hist_np[slot].tolist(),
                # per-request admit->retire latency; deliberately NOT named
                # wall_time_s (which in serve_all is the shared whole-batch
                # generate time — a different quantity)
                "latency_s": time.perf_counter() - req.stats["admit_t"],
            }
            if arm_pulls_np is not None:
                # the slot's bandit history, read BEFORE release zeroes it
                req.stats["arm_pulls"] = {
                    self._arms[a]: int(arm_pulls_np[slot, a])
                    for a in range(len(self._arms))
                    if arm_pulls_np[slot, a]}
                self._arm_pulls_total += arm_pulls_np[slot].astype(np.int64)
            state = self._run_release(state, slot)
            self._slots.release(slot)
            if self.paged:
                self._page_reserved.pop(slot, None)
            retired.append(req)
        self._cont_state = state
        return retired

    def _slot_pages(self, prompt_len: int, mnt: int) -> int:
        """Worst-case pool pages one request can ever occupy: the cache
        holds at most prompt_len + mnt + w positions (cur_len peaks at
        prompt_len + mnt - 1 and spec growth covers cur_len + w + 1; under
        adaptive arms w is the arm-table maximum — in-step growth is sized
        for the worst arm whichever arm the slot picks)."""
        return int(Cache.pages_for_len(prompt_len + mnt + self._cont_spec.w,
                                       self._page_size))

    def _reject(self, req: Request, reason: str) -> Request:
        """Per-request admission failure: the request completes with an
        ``error`` stat instead of silently-corrupted output."""
        req.output = None
        req.output_ids = np.zeros((0,), np.int32)
        req.stats = {"error": reason, "new_tokens": 0}
        self._rejected += 1
        warnings.warn(f"request {req.request_id} rejected: {reason}")
        return req

    def _admit_queued(self) -> List[Request]:
        """Admit queued prompts into free slots; returns requests REJECTED
        this round (prompt beyond capacity).  Paged mode additionally gates
        admission on pages-available (reservation), deferring the queue
        head — in order — until retirements free enough pages."""
        state = self._cont_state
        rejected: List[Request] = []
        free = self._slots.free_slots()
        i = 0
        while i < len(free):
            slot = free[i]
            head = self.scheduler.peek_next()
            if head is None:
                break
            req, toks, raw_len = head
            if toks.shape[0] > self._cont_prompt_cap:
                # the request's BUCKET does not fit the self-sized state:
                # admitting would truncate below its bucket and silently
                # corrupt the output.  (Prompts beyond the largest bucket
                # are left-clamped by the scheduler in both serving modes —
                # that is bucketing policy, not a continuous-mode hazard.)
                self.scheduler.pop_next()      # rejection frees no slot:
                rejected.append(self._reject(  # retry this slot with the
                    req,                       # next queued request
                    f"prompt is {raw_len} tokens ({toks.shape[0]}-bucket) "
                    f"but the continuous DecodeState was sized for "
                    f"{self._cont_prompt_cap} (pass buckets= / use paged "
                    f"mode to admit longer prompts)"))
                continue
            if req.temperature > 0 and not self._cont_spec.sampling:
                # the step was compiled greedy-only (sampling=False was
                # pinned, or the state was built before sampled traffic
                # arrived) — serving this request greedy would silently
                # break its output distribution, so reject loudly
                self.scheduler.pop_next()
                rejected.append(self._reject(
                    req,
                    f"temperature={req.temperature} needs a "
                    f"sampling-enabled step, but the continuous spec_step "
                    f"was compiled greedy-only (construct the engine with "
                    f"sampling=True, or queue sampled requests before the "
                    f"first step)"))
                continue
            mnt = min(req.max_new_tokens, self.max_new_cap)
            if self.paged:
                pages = self._slot_pages(toks.shape[0], mnt)
                if pages > self._pool_pages:
                    # can NEVER fit — deferring would deadlock an idle pool
                    self.scheduler.pop_next()
                    rejected.append(self._reject(
                        req,
                        f"request needs {pages} pages but the pool has "
                        f"only {self._pool_pages} (raise --num-pages)"))
                    continue
                avail = self._pool_pages - sum(self._page_reserved.values())
                if pages > avail:
                    # pool exhausted: defer the head (FIFO order is kept)
                    # until retirements return pages to the free stack
                    self._deferrals += 1
                    break
                self._page_reserved[slot] = pages
            self.scheduler.pop_next()
            if mnt < req.max_new_tokens:
                # static serve_all honours any budget (it sizes buffers per
                # batch); the continuous DecodeState is sized once by
                # max_new_cap, so an oversized request is clamped — loudly.
                warnings.warn(
                    f"request {req.request_id}: max_new_tokens "
                    f"{req.max_new_tokens} exceeds the engine's continuous "
                    f"max_new_cap={self.max_new_cap}; clamping (raise "
                    f"max_new_cap to honour larger budgets)")
            state = self._run_admit(state, slot, toks, mnt,
                                    self._effective_eos(req), req)
            self._slots.assign(slot, req)
            req.stats = {"admit_t": time.perf_counter()}
            i += 1
        self._cont_state = state
        return rejected

    def step(self) -> List[Request]:
        """One continuous-batching iteration: retire finished rows, admit
        queued prompts into the freed slots, then run one jitted spec_step
        over every active slot.  Returns the requests completed this step —
        retired normally, or rejected at admission (``stats["error"]``)."""
        if self._cont_state is None:
            self._init_continuous()
        retired = self._retire_finished()
        retired.extend(self._admit_queued())
        # occupancy is tracked host-side: after retirement every occupied
        # slot is runnable (an admission that hit eos on its first token is
        # retired next step; the one no-op spec_step it gets is rarer than
        # paying a device->host sync on every step to detect it).
        if len(self._slots):
            self._cont_state = self._run_step(self._cont_state)
            # peak-pool telemetry is NOT sampled here: reading free_top
            # back every step was a per-step device->host sync on the
            # decode critical path (repro-lint host-sync found it).  Pool
            # occupancy only ever falls at release, so sampling it at
            # retirement entry (before the frees) and in pool_stats()
            # observes every high-water mark syncs-free on the hot path.
        return retired

    def reset_pool_counters(self) -> None:
        """Zero the cumulative pool/bandit counters (peak pages, deferral
        rounds, rejections, retired arm pulls) without touching the pool or
        the in-flight bandit state — benchmarks call this after their
        warmup phase so the measured window starts clean."""
        if self._cont_state is None:
            return
        if self.paged:
            self._pool_peak = 0
            self._deferrals = 0
        if self._arm_pulls_total is not None:
            self._arm_pulls_total[:] = 0
        self._rejected = 0

    def pool_stats(self) -> Dict:
        """Paged-pool occupancy/admission counters (paged mode only).

        ``deferrals`` counts deferral ROUNDS — one per step() in which the
        queue head could not reserve pages — not distinct requests."""
        if not self.paged or self._cont_state is None:
            return {}
        free = int(np.asarray(self._cont_state.model["free_top"]))
        # fold current occupancy into the peak: step() no longer samples
        # it per step (that was a hot-path sync), so a caller reading
        # stats mid-flight still observes at least the occupancy it sees
        self._pool_peak = max(self._pool_peak, self._pool_pages - free)
        return {"num_pages": self._pool_pages,
                "page_size": self._page_size,
                "free_pages": free,
                "reserved_pages": sum(self._page_reserved.values()),
                "peak_pages": self._pool_peak,
                "deferrals": self._deferrals,
                "rejected": self._rejected}

    def mesh_report(self) -> Dict:
        """Resolved sharding of THIS engine's serving state ({} without a
        mesh): mesh shape, per-leaf DecodeState partition specs, param
        sharding coverage, and every (logical axis, dim) that silently
        degraded to replication — so a bench/operator can assert the mesh
        actually sharded the state instead of serving replicated at full
        per-device memory (distributed.sharding.ShardingFallbackWarning).
        """
        if self.mesh is None:
            return {}
        p_flat = jax.tree_util.tree_flatten_with_path(self.params)[0]
        p_sharded = sum(
            1 for _, leaf in p_flat
            if any(ax is not None for ax in leaf.sharding.spec))
        # re-resolve THIS engine's specs under a scoped recorder: the
        # report must list only fallbacks attributable to this engine's
        # params/state, not the process-global warning history (another
        # engine's mesh may have produced entirely different ones)
        with shd.recording_fallbacks() as fallbacks:
            shd.params_shardings(self.mesh, self.params)
            if self._cont_state is not None:
                shd.decode_state_shardings(self.mesh, self._cont_state)
        rep = {
            "mesh": {str(k): int(v) for k, v in self.mesh.shape.items()},
            "backend": "xla",   # a mesh pins attn_verify off the Pallas
                                # kernel (DESIGN.md §10 seam)
            "params_leaves": len(p_flat),
            "params_sharded": p_sharded,
            "replication_fallbacks": [list(kv) for kv in sorted(fallbacks)],
        }
        if self._cont_state is not None:
            specs = shd.spec_summary(self._state_shardings)
            rep["state_specs"] = specs
            rep["state_sharded"] = sum(
                1 for s in specs.values()
                if any(f"'{ax}'" in s for ax in self.mesh.shape))
        return rep

    def step_hlo(self) -> str:
        """Optimized HLO of the continuous spec_step for the CURRENT state
        shapes — the mesh bench extracts per-step collective bytes from it
        (launch/dryrun.collective_bytes).  Does not execute (donation is
        only consumed at execution), but the AOT lower().compile() is a
        FULL extra compile separate from the jit execution cache — so the
        text is memoized per engine (state shapes are fixed once the
        continuous path is initialised)."""
        if self._cont_state is None:
            self._init_continuous()
        if self._step_hlo_text is None:
            with self._act():
                if self._step_jit is not None:
                    lowered = self._step_jit.lower(
                        self.params, self._cont_state, self.tables)
                else:
                    lowered = spec_step.lower(
                        self.params, self.cfg, self._cont_spec,
                        self._cont_state, self.tables)
            self._step_hlo_text = lowered.compile().as_text()
        return self._step_hlo_text

    def adaptive_stats(self) -> Dict:
        """Continuous-mode bandit telemetry: the arm table, cumulative
        pulls per arm over all RETIRED requests, and each in-flight slot's
        current pull counts (adaptive continuous mode only)."""
        if self._arms is None or self._cont_state is None:
            return {}
        in_flight = np.asarray(self._cont_state.stats["arm_pulls"])
        return {"arms": [list(a) for a in self._arms],
                "pulls_retired": self._arm_pulls_total.tolist(),
                "pulls_in_flight": in_flight.sum(axis=0).tolist()}

    def serve_continuous(self) -> List[Request]:
        """Drain the queue with continuous batching; blocks until idle."""
        done: List[Request] = []
        while True:
            done.extend(self.step())
            if self.scheduler.pending() == 0 and self.in_flight() == 0:
                return done
