"""Equivalence tests for the spec_step refactor and continuous batching.

(a) driving generation one jitted spec_step at a time is bit-identical to
    the one-shot while_loop ``generate`` for every strategy;
(b) continuous serving with staggered admission/retirement, heterogeneous
    per-request max_new_tokens and eos truncation matches greedy_reference
    per request — and speculation is actually active (model calls strictly
    fewer than committed tokens for the mixed strategy).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ngram_tables import NGramTables, build_bigram, build_unigram
from repro.core.spec_engine import (SpecConfig, generate, greedy_reference,
                                    init_decode_state, spec_step)
from repro.models import model as M
from repro.serving import ServingEngine

pytestmark = pytest.mark.slow  # model-level suite; excluded from -m 'not slow' fast lane


def _tables(params, cfg, k_max=8, w_max=8):
    fwd = jax.jit(lambda t: M.forward(params, cfg, tokens=t)[0][:, -1])
    topk, chain = build_bigram(fwd, cfg.vocab_size, k_max=k_max, w_max=w_max,
                               batch=cfg.vocab_size)
    uni = build_unigram(params["embed"]["embedding"],
                        params["embed"].get("lm_head",
                                            params["embed"]["embedding"].T),
                        k_max=k_max)
    return NGramTables(uni, topk, chain)


def _drive_steps(params, cfg, spec, state, tables, max_steps=200):
    for _ in range(max_steps):
        if not bool(np.asarray(~state.done).any()):
            return state
        state = spec_step(params, cfg, spec, state, tables)
    raise AssertionError("spec_step did not converge")


# ---------------------------------------------------------------------------
# (a) step-driven == one-shot, bit for bit, for every strategy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", ["greedy", "bigram", "unigram",
                                      "context", "mixed"])
def test_spec_step_matches_generate(tiny_dense, strategy):
    cfg, params = tiny_dense
    tables = _tables(params, cfg)
    B, P, N = 2, 10, 24
    prompt = jax.random.randint(jax.random.PRNGKey(5), (B, P), 0,
                                cfg.vocab_size)
    spec = SpecConfig(k=4, w=3, q=1, strategy=strategy, max_new_tokens=N)
    buf, blen, stats = generate(params, cfg, spec, prompt, tables)
    state = init_decode_state(params, cfg, spec, prompt)
    state = _drive_steps(params, cfg, spec, state, tables)
    np.testing.assert_array_equal(np.asarray(buf), np.asarray(state.buf))
    np.testing.assert_array_equal(np.asarray(blen),
                                  np.asarray(state.buf_len))
    for key in stats:
        np.testing.assert_array_equal(np.asarray(stats[key]),
                                      np.asarray(state.stats[key]),
                                      err_msg=f"stats[{key}] diverged")


@pytest.mark.parametrize("strategy", ["greedy", "mixed"])
def test_spec_step_recurrent_continuous(tiny_xlstm_cfg, strategy):
    """Recurrent (mLSTM/sLSTM) archs through the continuous path: staggered
    admission via the donated admit_slot/spec_step jits (regression for the
    shared-zeros-buffer donation failure) must match greedy_reference."""
    from repro.core.spec_engine import admit_slot, empty_decode_state
    cfg = tiny_xlstm_cfg
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tables = _tables(params, cfg) if strategy == "mixed" else None
    B, P, N = 2, 8, 10
    prompt = jax.random.randint(jax.random.PRNGKey(3), (B, P), 0,
                                cfg.vocab_size)
    ref = greedy_reference(params, cfg, prompt, N)
    spec = SpecConfig(k=3, w=3, strategy=strategy, max_new_tokens=N)
    state = empty_decode_state(cfg, spec, 2, P + N + spec.w + 2)
    state = admit_slot(params, cfg, state, jnp.int32(0), prompt[0],
                       jnp.int32(N), jnp.int32(-1))
    state = spec_step(params, cfg, spec, state, tables)   # slot 1 still free
    state = admit_slot(params, cfg, state, jnp.int32(1), prompt[1],
                       jnp.int32(N), jnp.int32(-1))
    state = _drive_steps(params, cfg, spec, state, tables)
    for b in range(B):
        np.testing.assert_array_equal(np.asarray(state.buf[b, P:P + N]),
                                      np.asarray(ref[b, P:]))


def test_spec_step_heterogeneous_budgets_and_eos(tiny_dense):
    """Per-slot budgets/eos on a single shared DecodeState."""
    cfg, params = tiny_dense
    tables = _tables(params, cfg)
    B, P, N = 2, 10, 24
    prompt = jax.random.randint(jax.random.PRNGKey(5), (B, P), 0,
                                cfg.vocab_size)
    ref = greedy_reference(params, cfg, prompt, N)
    eos = int(ref[1, P + 5])            # row 1 stops at its first eos hit
    spec = SpecConfig(k=4, w=3, strategy="mixed", max_new_tokens=N)
    state = init_decode_state(params, cfg, spec, prompt,
                              max_new_tokens=jnp.asarray([13, N]),
                              eos_id=jnp.asarray([-1, eos]))
    state = _drive_steps(params, cfg, spec, state, tables)
    np.testing.assert_array_equal(np.asarray(state.buf[0, P:P + 13]),
                                  np.asarray(ref[0, P:P + 13]))
    assert int(state.buf_len[0]) == P + 13
    n1 = int(state.buf_len[1]) - P
    first = list(np.asarray(ref[1, P:])).index(eos)
    assert n1 == first + 1
    np.testing.assert_array_equal(np.asarray(state.buf[1, P:P + n1]),
                                  np.asarray(ref[1, P:P + first + 1]))


# ---------------------------------------------------------------------------
# (b) continuous serving == greedy_reference per request
# ---------------------------------------------------------------------------
def _reference_ids(eng, params, cfg, prompt: str, max_new: int,
                   eos_id: int = -1):
    """Expected output ids: greedy on the same padded prompt, truncated at
    the first eos (inclusive) exactly like the engine."""
    padded = eng.scheduler.pad_to_bucket(eng.tok.encode(prompt))[None]
    ref = greedy_reference(params, cfg, jnp.asarray(padded), max_new)
    out = list(np.asarray(ref[0, padded.shape[1]:]))
    if eos_id >= 0 and eos_id in out:
        out = out[:out.index(eos_id) + 1]
    return np.asarray(out, np.int32)


@pytest.mark.parametrize("strategy", ["mixed", "greedy"])
def test_continuous_staggered_matches_reference(tiny_dense, strategy):
    cfg, params = tiny_dense
    spec = SpecConfig(k=4, w=3, strategy=strategy, max_new_tokens=24)
    tables = _tables(params, cfg) if strategy != "greedy" else None
    eng = ServingEngine(params, cfg, spec, tables=tables, max_batch=2,
                        buckets=(16,), max_new_cap=24)
    # r4 stops on an eos forced onto its own greedy trajectory
    eos4 = int(_reference_ids(eng, params, cfg, "eos victim", 24)[6])
    # wave 1: two requests with different budgets
    r1 = eng.submit("hello world", max_new_tokens=18)
    r2 = eng.submit("a rather different prompt", max_new_tokens=9)
    for _ in range(2):
        eng.step()
    # wave 2 arrives mid-flight (slots retire/admit between spec_steps)
    r3 = eng.submit("late arrival", max_new_tokens=21)
    r4 = eng.submit("eos victim", max_new_tokens=24, eos_id=eos4)
    done = eng.serve_continuous()
    reqs = {r.request_id: r for r in done}
    assert sorted(reqs) == sorted(r.request_id for r in (r1, r2, r3, r4))
    for req in (r1, r2, r3, r4):
        expect = _reference_ids(eng, params, cfg, req.prompt,
                                req.max_new_tokens, req.eos_id)
        np.testing.assert_array_equal(reqs[req.request_id].output_ids, expect,
                                      err_msg=f"request {req.request_id}")
        assert reqs[req.request_id].stats["new_tokens"] == len(expect)
    assert reqs[r4.request_id].output_ids[-1] == eos4   # eos truncation hit
    assert reqs[r4.request_id].stats["new_tokens"] <= 7
    if strategy == "mixed":
        # speculation must be active: strictly fewer verify calls than tokens
        for req in (r1, r3):
            st = reqs[req.request_id].stats
            assert st["model_calls"] < st["new_tokens"], (
                req.request_id, st)


def test_eos_symmetric_between_modes(tiny_dense):
    """A submission with a per-request eos stops identically under static
    serve_all and continuous serve_continuous."""
    cfg, params = tiny_dense
    spec = SpecConfig(k=4, w=3, strategy="mixed", max_new_tokens=20)
    tables = _tables(params, cfg)
    outs = {}
    for mode in ("static", "continuous"):
        eng = ServingEngine(params, cfg, spec, tables=tables, max_batch=2,
                            buckets=(16,), max_new_cap=20)
        if mode == "static":
            eos = int(_reference_ids(eng, params, cfg, "stop me", 20)[4])
        eng.submit("stop me", max_new_tokens=20, eos_id=eos)
        done = (eng.serve_all() if mode == "static"
                else eng.serve_continuous())
        outs[mode] = done[0].output_ids
        assert done[0].output_ids[-1] == eos
    np.testing.assert_array_equal(outs["static"], outs["continuous"])


def test_slot_reuse_no_cross_request_leakage(tiny_dense):
    """One slot, several sequential requests: each output must equal the
    request's isolated greedy reference (any cache residue would diverge)."""
    cfg, params = tiny_dense
    spec = SpecConfig(k=4, w=3, strategy="mixed", max_new_tokens=16)
    eng = ServingEngine(params, cfg, spec, tables=_tables(params, cfg),
                        max_batch=1, buckets=(16,), max_new_cap=16)
    prompts = ["first request", "second, quite unlike the first",
               "third!"]
    reqs = [eng.submit(p, max_new_tokens=16) for p in prompts]
    done = eng.serve_continuous()
    assert len(done) == 3
    for req in reqs:
        expect = _reference_ids(eng, params, cfg, req.prompt,
                                req.max_new_tokens)
        got = next(r for r in done if r.request_id == req.request_id)
        np.testing.assert_array_equal(got.output_ids, expect)


def test_overlong_prompt_rejected_not_truncated(tiny_dense):
    """A prompt beyond the self-sized continuous buffer is REJECTED with a
    per-request error stat — truncating it would silently decode from a
    corrupted (cut-off) context.  Regression for the warn-and-truncate
    behaviour this replaced."""
    cfg, params = tiny_dense
    spec = SpecConfig(k=4, w=3, strategy="greedy", max_new_tokens=8)
    eng = ServingEngine(params, cfg, spec, max_batch=1, max_new_cap=8)
    short = eng.submit("short", max_new_tokens=8)        # 32-bucket wave
    eng.step()                       # engine self-sizes for 32 tokens
    long = eng.submit("x" * 40, max_new_tokens=8)        # needs 64 bucket
    done = eng.serve_continuous()
    reqs = {r.request_id: r for r in done}
    assert sorted(reqs) == sorted([short.request_id, long.request_id])
    assert "error" in reqs[long.request_id].stats
    assert reqs[long.request_id].output is None
    assert reqs[long.request_id].stats["new_tokens"] == 0
    assert "error" not in reqs[short.request_id].stats
    assert reqs[short.request_id].stats["new_tokens"] == 8


def test_adaptive_continuous_no_longer_raises(tiny_dense):
    """Regression for the REMOVED NotImplementedError branch: adaptive=True
    over the continuous path now serves (shape-stable arm masking,
    DESIGN.md §9) — the old error and its documented masking-workaround
    text are gone.  Full parity/bandit coverage lives in
    tests/test_adaptive_continuous.py; this pins the error path's removal
    where the error was originally asserted."""
    cfg, params = tiny_dense
    eng = ServingEngine(params, cfg,
                        SpecConfig(k=4, w=3, strategy="mixed",
                                   max_new_tokens=8),
                        tables=_tables(params, cfg), adaptive=True,
                        arms=((1, 0), (4, 3)), max_batch=1, buckets=(16,),
                        max_new_cap=8)
    r = eng.submit("hello", max_new_tokens=8)
    done = eng.serve_continuous()           # must not raise
    assert [q.request_id for q in done] == [r.request_id]
    assert done[0].stats["new_tokens"] == 8
    assert "arm_pulls" in done[0].stats


def test_continuous_throughput_stats(tiny_dense):
    """Per-request stats survive slot reuse: calls/token counters are reset
    at admission, so a recycled slot reports only its own request."""
    cfg, params = tiny_dense
    spec = SpecConfig(k=4, w=3, strategy="mixed", max_new_tokens=12)
    eng = ServingEngine(params, cfg, spec, tables=_tables(params, cfg),
                        max_batch=1, buckets=(16,), max_new_cap=12)
    a = eng.submit("aaaa", max_new_tokens=12)
    b = eng.submit("bbbb", max_new_tokens=5)
    done = eng.serve_continuous()
    stats = {r.request_id: r.stats for r in done}
    assert stats[a.request_id]["new_tokens"] == 12
    assert stats[b.request_id]["new_tokens"] == 5
    # slot stats were zeroed between requests: b cannot have inherited a's
    # call count (a needs >= ceil(12 / (w+2)) calls; b <= its own 5)
    assert 1 <= stats[b.request_id]["model_calls"] <= 5
    assert stats[a.request_id]["model_calls"] >= 3
    for st in stats.values():
        assert st["latency_s"] > 0
