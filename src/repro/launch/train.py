"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains reduced (smoke) configs on the synthetic
corpus; on a real cluster the same entry point pjits the identical
train_step over make_production_mesh() (the dry-run proves those shardings
compile for every assigned arch — see launch/dryrun.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.data.pipeline import mixed_batches
from repro.train import AdamWConfig, init_train_state, make_train_step
from repro.train.checkpoint import save


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, default="mistral-7b")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use the reduced config (CPU container default)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--save", default="")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.embedding_inputs:
        raise SystemExit(f"{args.arch}: embedding-input arch; use the "
                         "frontend-stub training path in tests/benchmarks")
    print(f"arch={cfg.name} params={cfg.param_count():,}")
    ts = init_train_state(jax.random.PRNGKey(0), cfg)
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(args.steps // 10, 1))
    step = jax.jit(make_train_step(cfg, opt, remat=False))
    t0 = time.time()
    for i, b in enumerate(mixed_batches(args.batch, args.seq, args.steps)):
        ts, m = step(ts, jnp.asarray(b))
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"ppl={float(m['ppl']):.1f} "
                  f"lr={float(m['lr']):.2e} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    if args.save:
        save(args.save, ts["params"])
        print("saved ->", args.save)


if __name__ == "__main__":
    main()
