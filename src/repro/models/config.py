"""Model configuration covering every assigned architecture family.

One dataclass describes dense / GQA / MQA / MoE / Mamba / xLSTM / hybrid /
encoder-only / VLM+audio-backbone decoders.  Layers are described by a
repeating ``block_pattern`` of (mixer, mlp) pairs so heterogeneous stacks
(Jamba's 1:7 attention:mamba interleave, xLSTM's mLSTM/sLSTM mix,
DeepSeek-MoE's dense first layer) compile to a compact scan-over-periods HLO.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

# Mixer kinds (sequence-mixing sublayer).
ATTN = "attn"
MAMBA = "mamba"
MLSTM = "mlstm"
SLSTM = "slstm"

# MLP kinds (channel-mixing sublayer).
SWIGLU = "swiglu"
GEGLU = "geglu"
RELU2 = "relu2"  # squared-ReLU (Nemotron-4)
GELU = "gelu"    # plain 2-layer GELU MLP (HuBERT)
MOE = "moe"
NO_MLP = "none"  # xLSTM blocks carry their own projections

ROPE_NONE = "none"
ROPE = "rope"
MROPE = "mrope"  # Qwen2-VL multimodal 3D RoPE


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One layer position inside the repeating pattern."""
    mixer: str = ATTN
    mlp: str = SWIGLU


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    arch_type: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""          # citation (arXiv id / model card)

    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0          # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 512

    # Repeating layer pattern; len must divide num_layers (after prefix).
    block_pattern: Tuple[BlockSpec, ...] = (BlockSpec(),)
    # Layers preceding the periodic body (e.g. DeepSeek-MoE dense layer 0).
    prefix_blocks: Tuple[BlockSpec, ...] = ()

    # Norm
    norm: str = "rmsnorm"      # rmsnorm | layernorm
    norm_eps: float = 1e-5
    # Whether attention/mlp use parallel residual (not used by assigned archs)
    qk_norm: bool = False

    # Positional encoding
    rope: str = ROPE
    rope_theta: float = 10_000.0
    partial_rotary_factor: float = 1.0   # StableLM-2: 0.25, Nemotron: 0.5
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w rotary halves

    # Attention
    causal: bool = True
    sliding_window: Optional[int] = None  # Mixtral: 4096
    attn_logit_softcap: Optional[float] = None

    # Kernel dispatch (kernels/dispatch.py): "xla" | "pallas" | "auto".
    # "auto" resolves to pallas on TPU and xla elsewhere; "pallas" off-TPU
    # runs the kernels in interpret mode (the parity-test configuration).
    backend: str = "auto"
    # Cache block (sequence slots per VMEM block) for the Pallas verify
    # kernel; 0 = kernel default (512).  Serving aligns its DecodeState
    # buffers to this so the kernel never repads per step.
    kernel_block_s: int = 0

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 2
    num_shared_experts: int = 0   # DeepSeek-MoE: 2
    moe_d_ff: int = 0             # expert width (DeepSeek fine-grained: 1408)
    router_aux_loss_coef: float = 0.01
    moe_impl: str = "scatter"     # scatter | dense (dense = oracle for tests)
    capacity_factor: float = 2.0

    # Mamba (Jamba)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0        # 0 -> ceil(d_model / 16)

    # xLSTM
    xlstm_mlstm_proj_factor: float = 2.0
    xlstm_slstm_proj_factor: float = 4.0 / 3.0
    xlstm_conv_kernel: int = 4

    # Embedding / head
    tie_embeddings: bool = False
    scale_embed: bool = False     # Gemma: x * sqrt(d_model)
    encoder_only: bool = False    # HuBERT: bidirectional, no decode path
    # Modality frontend stub: inputs are precomputed embeddings, not tokens.
    embedding_inputs: bool = False

    # Gemma-style GeGLU uses approximate tanh gelu
    gelu_approx: bool = True

    param_dtype: jnp.dtype = jnp.bfloat16
    compute_dtype: jnp.dtype = jnp.bfloat16

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def rotary_dim(self) -> int:
        rd = int(self.resolved_head_dim * self.partial_rotary_factor)
        return rd - (rd % 2)

    @property
    def body_layers(self) -> int:
        return self.num_layers - len(self.prefix_blocks)

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    @property
    def num_periods(self) -> int:
        assert self.body_layers % self.pattern_period == 0, (
            f"{self.name}: body layers {self.body_layers} not divisible by "
            f"pattern period {self.pattern_period}")
        return self.body_layers // self.pattern_period

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff else self.d_ff

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.mamba_dt_rank if self.mamba_dt_rank else -(-self.d_model // 16)

    def validate(self) -> "ModelConfig":
        assert self.num_heads % self.num_kv_heads == 0, self.name
        assert self.backend in ("xla", "pallas", "auto"), self.backend
        _ = self.num_periods
        for b in tuple(self.prefix_blocks) + tuple(self.block_pattern):
            assert b.mixer in (ATTN, MAMBA, MLSTM, SLSTM), b
            assert b.mlp in (SWIGLU, GEGLU, RELU2, GELU, MOE, NO_MLP), b
            if b.mlp == MOE:
                assert self.num_experts > 0, self.name
        if self.encoder_only:
            assert not self.causal
        return self

    # Parameter count (for 6ND roofline math). Counts active params for MoE
    # when ``active_only`` is set.
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d  # input embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        total = n
        blocks = list(self.prefix_blocks)
        blocks += list(self.block_pattern) * self.num_periods
        for b in blocks:
            if b.mixer == ATTN:
                total += d * (self.num_heads * hd) * 2  # q, o
                total += d * (self.num_kv_heads * hd) * 2  # k, v
            elif b.mixer == MAMBA:
                di = self.mamba_d_inner
                total += d * di * 2  # in_proj (x and z)
                total += di * self.mamba_d_conv  # conv
                total += di * (self.resolved_dt_rank + 2 * self.mamba_d_state)
                total += self.resolved_dt_rank * di + di * self.mamba_d_state
                total += di * d  # out proj
            elif b.mixer == MLSTM:
                di = int(self.d_model * self.xlstm_mlstm_proj_factor)
                total += d * di * 2 + di * di * 3 + 3 * di + di * d
            elif b.mixer == SLSTM:
                total += 4 * d * d + d * int(self.d_model *
                                             self.xlstm_slstm_proj_factor) * 2
            if b.mlp in (SWIGLU, GEGLU):
                total += 3 * d * self.d_ff
            elif b.mlp in (RELU2, GELU):
                total += 2 * d * self.d_ff
            elif b.mlp == MOE:
                e_ff = self.expert_d_ff
                eff_experts = self.num_experts + self.num_shared_experts
                if active_only:
                    eff_experts = self.num_experts_per_tok + self.num_shared_experts
                total += eff_experts * 3 * d * e_ff
                total += d * self.num_experts  # router
        return total


def layer_blocks(cfg: ModelConfig) -> Tuple[BlockSpec, ...]:
    """Full per-layer block list (prefix + periodic body expanded)."""
    return tuple(cfg.prefix_blocks) + tuple(cfg.block_pattern) * cfg.num_periods
