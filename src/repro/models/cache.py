"""Decode-state management: KV caches (linear + sliding-window ring), SSM and
xLSTM recurrent states, and the speculative *commit* semantics.

Paper mapping (Appendix D): the paper keeps a batched (k-row) static KV cache,
initialised from a k=1 cache by broadcasting, and after each verification
overwrites all rows with the winning row's accepted entries.  Our TPU-native
default is the *bifurcated* variant instead: ONE shared cache of the context,
per-row KV only for the in-flight (w+1)-token speculative tail; commit writes
the winner's accepted tail into the shared cache.  This removes the k× HBM
traffic (and k× memory) of the paper's layout — see DESIGN.md §3 and
EXPERIMENTS.md §Perf where both layouts are measured.

State layout (everything stacked over the R periods of the layer pattern so
the transformer can ``lax.scan`` over it):

  state = {
    "cur_len": (B,) int32   — #positions committed per sequence,
    "groups": {gid: {...}}  — gid = "pre{i}" or "p{j}"; every leaf has
                               leading dim R (R=1 for prefix groups).
  }
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ATTN, MAMBA, MLSTM, SLSTM, BlockSpec, ModelConfig


def cache_buffer_len(cfg: ModelConfig, max_len: int) -> int:
    """Physical KV buffer length: window-sized ring when sliding-window."""
    if cfg.sliding_window is not None and cfg.sliding_window < max_len:
        return cfg.sliding_window
    return max_len


def group_ids(cfg: ModelConfig):
    """Yield (gid, BlockSpec, R) for prefix and body pattern positions."""
    out = []
    for i, b in enumerate(cfg.prefix_blocks):
        out.append((f"pre{i}", b, 1))
    for j, b in enumerate(cfg.block_pattern):
        out.append((f"p{j}", b, cfg.num_periods))
    return out


def init_state(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """Allocate an empty decode state for ``batch`` sequences."""
    S = cache_buffer_len(cfg, max_len)
    hd = cfg.resolved_head_dim
    groups = {}
    for gid, spec, R in group_ids(cfg):
        if spec.mixer == ATTN:
            shape = (R, batch, S, cfg.num_kv_heads, hd)
            groups[gid] = {"k": jnp.zeros(shape, cfg.compute_dtype),
                           "v": jnp.zeros(shape, cfg.compute_dtype)}
        elif spec.mixer == MAMBA:
            groups[gid] = {
                "conv": jnp.zeros((R, batch, cfg.mamba_d_conv - 1,
                                   cfg.mamba_d_inner), cfg.compute_dtype),
                "ssm": jnp.zeros((R, batch, cfg.mamba_d_inner,
                                  cfg.mamba_d_state), jnp.float32)}
        elif spec.mixer == MLSTM:
            di = int(cfg.d_model * cfg.xlstm_mlstm_proj_factor)
            nh = cfg.num_heads
            dh = di // nh
            groups[gid] = {
                "C": jnp.zeros((R, batch, nh, dh, dh), jnp.float32),
                "n": jnp.zeros((R, batch, nh, dh), jnp.float32),
                "m": jnp.full((R, batch, nh), -1e9, jnp.float32),
                "conv": jnp.zeros((R, batch, cfg.xlstm_conv_kernel - 1, di),
                                  cfg.compute_dtype)}
        elif spec.mixer == SLSTM:
            nh = cfg.num_heads
            dh = cfg.d_model // nh
            # distinct buffers per leaf: sharing one zeros array here makes
            # donation of the enclosing state illegal ("same buffer donated
            # twice" in the jitted admit/spec-step path)
            z = lambda: jnp.zeros((R, batch, nh, dh), jnp.float32)
            groups[gid] = {"c": z(), "n": z(), "h": z(),
                           "m": jnp.full((R, batch, nh, dh), -1e9, jnp.float32)}
    return {"cur_len": jnp.zeros((batch,), jnp.int32), "groups": groups}


# ----------------------------------------------------------------------------
# slot management (continuous batching)
# ----------------------------------------------------------------------------
def insert_slot(state: Dict, row_state: Dict, slot) -> Dict:
    """Overwrite batch slot ``slot`` of ``state`` with a batch-1 state.

    ``row_state`` comes from prefilling one request in isolation (batch 1,
    same ``max_len``); writing it over the slot replaces *every* leaf of the
    previous occupant — KV rows, recurrent states and cur_len — so request
    N+1 in a reused slot cannot observe request N's cache.  ``slot`` may be
    a traced scalar (jit-compatible admission).
    """
    def ins(leaf, row):
        if leaf.shape[2:] != row.shape[2:] or row.shape[1] != 1:
            raise ValueError(f"slot insert shape mismatch: {leaf.shape} "
                             f"vs {row.shape}")
        return leaf.at[:, slot].set(row[:, 0])

    groups = {gid: jax.tree_util.tree_map(ins, g, row_state["groups"][gid])
              for gid, g in state["groups"].items()}
    return {"cur_len": state["cur_len"].at[slot].set(row_state["cur_len"][0]),
            "groups": groups}


def reset_slot(cfg: ModelConfig, state: Dict, slot) -> Dict:
    """Reset batch slot ``slot`` to the freshly-initialised empty state.

    Passing the existing physical buffer length S back through init_state is
    shape-stable: cache_buffer_len(cfg, S) == S whether S came from a linear
    cache or a window-sized ring, and recurrent leaves ignore max_len.
    """
    S = 1
    for gid, spec, _ in group_ids(cfg):
        if spec.mixer == ATTN:
            S = state["groups"][gid]["k"].shape[2]
            break
    return insert_slot(state, init_state(cfg, 1, S), slot)


# ----------------------------------------------------------------------------
# position bookkeeping
# ----------------------------------------------------------------------------
def key_positions(cfg: ModelConfig, S: int, cur_len: jnp.ndarray) -> jnp.ndarray:
    """Absolute position stored in each cache slot; -1 where empty.

    cur_len: (B,). Linear cache: slot s holds position s if s < cur_len.
    Ring cache (window W=S): slot s holds the largest p < cur_len with
    p % W == s, valid if p >= 0 and p >= cur_len - W.
    """
    B = cur_len.shape[0]
    slots = jnp.arange(S)[None, :]                      # (1, S)
    cl = cur_len[:, None]                               # (B, 1)
    if cfg.sliding_window is not None and cfg.sliding_window <= S:
        # ring semantics
        p = cl - 1 - jnp.mod(cl - 1 - slots, S)
        valid = (p >= 0) & (p >= cl - S) & (cl > 0)
        return jnp.where(valid, p, -1).astype(jnp.int32)
    pos = jnp.broadcast_to(slots, (B, S))
    return jnp.where(pos < cl, pos, -1).astype(jnp.int32)


def write_slots(cfg: ModelConfig, S: int, cur_len: jnp.ndarray,
                T_new: int) -> jnp.ndarray:
    """Cache slots for the next T_new positions. (B, T_new) int32."""
    pos = cur_len[:, None] + jnp.arange(T_new)[None, :]
    if cfg.sliding_window is not None and cfg.sliding_window <= S:
        return jnp.mod(pos, S).astype(jnp.int32)
    return pos.astype(jnp.int32)


def kv_write(k_cache: jnp.ndarray, v_cache: jnp.ndarray,
             k_new: jnp.ndarray, v_new: jnp.ndarray,
             slots: jnp.ndarray,
             gate: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray,
                                                          jnp.ndarray]:
    """Write new KV into slots. caches: (B,S,KV,hd); new: (B,T,KV,hd);
    slots: (B,T). ``gate``: (B,T) bool — write only where True (spec commit).

    T == 1 (the production serve step) uses a one-hot masked select instead
    of a scatter: elementwise ops partition cleanly when the cache sequence
    dim is sharded over the `model` axis, whereas a scatter with dynamic
    per-row indices makes GSPMD all-gather the whole cache every layer
    (EXPERIMENTS §Perf it-6).  Multi-token writes (speculative verify
    commits) keep the scatter path.
    """
    B, T = slots.shape
    S = k_cache.shape[1]
    if T == 1:
        hit = (jnp.arange(S)[None, :] == slots)            # (B, S)
        if gate is not None:
            hit = hit & gate
        m = hit[..., None, None]
        k_cache = jnp.where(m, k_new.astype(k_cache.dtype), k_cache)
        v_cache = jnp.where(m, v_new.astype(v_cache.dtype), v_cache)
        return k_cache, v_cache
    b_idx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, T))
    if gate is not None:
        old_k = k_cache[b_idx, slots]
        old_v = v_cache[b_idx, slots]
        k_new = jnp.where(gate[..., None, None], k_new.astype(k_cache.dtype),
                          old_k)
        v_new = jnp.where(gate[..., None, None], v_new.astype(v_cache.dtype),
                          old_v)
    k_cache = k_cache.at[b_idx, slots].set(k_new.astype(k_cache.dtype))
    v_cache = v_cache.at[b_idx, slots].set(v_new.astype(v_cache.dtype))
    return k_cache, v_cache


def prefill_write(cfg: ModelConfig, k_cache, v_cache, k_new, v_new,
                  seq_mask: Optional[jnp.ndarray] = None):
    """Write a full prefill block (positions 0..T-1) into an empty cache.

    With a ring cache only the last S positions land (earlier ones are
    overwritten by the mod-S scatter, in order, which is exactly ring
    semantics).
    """
    B, T = k_new.shape[:2]
    S = k_cache.shape[1]
    if T > S:
        # ring cache shorter than the prompt: only the last S positions land
        # (slice explicitly — a mod-S scatter with duplicate slots would have
        # undefined winner order).
        k_new, v_new = k_new[:, -S:], v_new[:, -S:]
        if seq_mask is not None:
            seq_mask = seq_mask[:, -S:]
        off = jnp.full((B,), T - S, jnp.int32)
        slots = write_slots(cfg, S, off, S)
        return kv_write(k_cache, v_cache, k_new, v_new, slots, gate=seq_mask)
    cur0 = jnp.zeros((B,), jnp.int32)
    slots = write_slots(cfg, S, cur0, T)
    return kv_write(k_cache, v_cache, k_new, v_new, slots, gate=seq_mask)


# ----------------------------------------------------------------------------
# recurrent-state select helpers (used by gated replay commit)
# ----------------------------------------------------------------------------
def select_step_state(states_per_step, old_state, n_commit: jnp.ndarray):
    """states_per_step: pytree with leading (B, T, ...) per-step states;
    old_state: matching (B, ...). Returns state after n_commit steps
    (old state where n_commit == 0)."""
    def sel(per_step, old):
        B, T = per_step.shape[:2]
        idx = jnp.clip(n_commit - 1, 0, T - 1)
        picked = jnp.take_along_axis(
            per_step, idx.reshape((B,) + (1,) * (per_step.ndim - 1)), axis=1
        )[:, 0]
        return jnp.where(
            (n_commit > 0).reshape((B,) + (1,) * (old.ndim - 1)), picked, old)
    return jax.tree_util.tree_map(sel, states_per_step, old_state)
