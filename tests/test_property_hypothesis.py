"""Property-based tests (hypothesis) over the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core.drafters import context_ngram_draft
from repro.core.verify import accept, masked_acceptance

pytestmark = pytest.mark.slow  # model-level suite; excluded from -m 'not slow' fast lane

SETTINGS = dict(max_examples=30, deadline=None,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


@given(st.integers(0, 2**31 - 1), st.integers(1, 4), st.integers(1, 5),
       st.integers(2, 6))
@settings(**SETTINGS)
def test_accept_invariants(seed, k, w, vocab):
    """For ANY drafts/greedy: 1 <= n_commit <= w+1; committed tokens are a
    prefix of the winner's greedy sequence semantics."""
    rng = np.random.default_rng(seed)
    drafts = jnp.asarray(rng.integers(0, vocab, (1, k, w)), jnp.int32)
    greedy = jnp.asarray(rng.integers(0, vocab, (1, k, w + 1)), jnp.int32)
    a = accept(drafts, greedy)
    n = int(a.n_commit[0])
    assert 1 <= n <= w + 1
    wi = int(a.winner[0])
    # all rows' n_acc <= winner's
    assert int(a.n_acc[0].max()) == int(a.n_acc[0, wi])
    # committed tokens: first n-1 equal the winner's draft prefix,
    # last equals greedy after that prefix
    toks = np.asarray(a.tokens[0, :n])
    np.testing.assert_array_equal(toks[:n - 1],
                                  np.asarray(drafts[0, wi, :n - 1]))
    assert toks[n - 1] == int(greedy[0, wi, n - 1])


@given(st.integers(0, 2**31 - 1), st.integers(1, 3), st.integers(1, 4),
       st.integers(1, 4))
@settings(**SETTINGS)
def test_context_drafts_exist_in_context(seed, q, w, k):
    """Every valid context draft must literally follow a query match in the
    committed context (no hallucinated drafts; hash collisions only ever
    merge counts, never invent continuations)."""
    rng = np.random.default_rng(seed)
    L = 48
    cur = int(rng.integers(q + 1, L))
    buf = rng.integers(0, 4, L).astype(np.int32)
    d, v = context_ngram_draft(jnp.asarray(buf[None]),
                               jnp.asarray([cur]), q, k, w)
    query = list(buf[cur - q:cur])
    continuations = set()
    for i in range(0, cur - q - w + 1):
        if list(buf[i:i + q]) == query:
            continuations.add(tuple(buf[i + q:i + q + w]))
    for i in range(k):
        if bool(v[0, i]):
            assert tuple(np.asarray(d[0, i])) in continuations


@given(st.integers(0, 2**31 - 1), st.integers(1, 4), st.integers(1, 5))
@settings(**SETTINGS)
def test_masked_accept_equals_submatrix(seed, k, w):
    """For ANY drafts/greedy and ANY mask (k_eff, w_eff): acceptance under
    per-slot masking inside the (k, w) box is EXACTLY acceptance on the
    (k_eff, w_eff) sub-problem — the algebraic core of the shape-stable
    masking contract (DESIGN.md §9)."""
    rng = np.random.default_rng(seed)
    ke, we = int(rng.integers(1, k + 1)), int(rng.integers(0, w + 1))
    drafts = jnp.asarray(rng.integers(0, 3, (1, k, w)), jnp.int32)
    greedy = jnp.asarray(rng.integers(0, 3, (1, k, w + 1)), jnp.int32)
    m = accept(drafts, greedy, k_eff=jnp.asarray([ke]),
               w_eff=jnp.asarray([we]))
    assert int(m.winner[0]) < ke
    n = int(m.n_commit[0])
    assert 1 <= n <= we + 1
    if we == 0:     # pure greedy arm: single bonus token from row 0
        assert int(m.winner[0]) == 0 and n == 1
        assert int(m.tokens[0, 0]) == int(greedy[0, 0, 0])
        return
    d = accept(drafts[:, :ke, :we], greedy[:, :ke, :we + 1])
    assert int(m.winner[0]) == int(d.winner[0])
    assert n == int(d.n_commit[0])
    np.testing.assert_array_equal(np.asarray(m.tokens[0, :n]),
                                  np.asarray(d.tokens[0, :n]))


@given(st.integers(0, 2**31 - 1), st.integers(1, 5), st.integers(1, 5))
@settings(**SETTINGS)
def test_masked_acceptance_degenerate_masks(seed, k, w):
    """masked_acceptance under ANY mask combination — including the
    degenerate corners its docstring promises: w_eff == 0 (pure greedy,
    every n_acc zeroed), k_eff == 1 (row 0 the only candidate), an
    all-False eq (bonus-only), and a row_mask that excludes everything but
    row 0 (the all-0 tree path, eligible by construction)."""
    rng = np.random.default_rng(seed)
    eq = jnp.asarray(rng.integers(0, 2, (1, k, w)), bool)
    ke = int(rng.integers(1, k + 1))
    we = int(rng.integers(0, w + 1))
    rm = rng.integers(0, 2, (1, k)).astype(bool)
    rm[0, 0] = True                      # at least one eligible row, always
    n_acc, n_rank = masked_acceptance(eq, k_eff=jnp.asarray([ke]),
                                      w_eff=jnp.asarray([we]),
                                      row_mask=jnp.asarray(rm))
    n_acc, n_rank = np.asarray(n_acc[0]), np.asarray(n_rank[0])
    # n_acc: depth-truncated prefix length, independent of eligibility
    for i in range(k):
        run = 0
        for j in range(min(we, w)):
            if not bool(eq[0, i, j]):
                break
            run += 1
        assert n_acc[i] == run
    # n_rank: -1 exactly on ineligible rows, n_acc elsewhere
    for i in range(k):
        eligible = (i < ke) and bool(rm[0, i])
        assert n_rank[i] == (n_acc[i] if eligible else -1)
    # a winner always exists and is eligible (row 0 guarantees >= 0)
    wi = int(np.argmax(n_rank))
    assert n_rank[wi] >= 0 and wi < ke and bool(rm[0, wi])
    if we == 0:
        assert (n_acc == 0).all()        # pure greedy: bonus token only
    # degenerate eq: nothing accepted anywhere
    z_acc, z_rank = masked_acceptance(jnp.zeros((1, k, w), bool),
                                      k_eff=jnp.asarray([ke]),
                                      w_eff=jnp.asarray([we]),
                                      row_mask=jnp.asarray(rm))
    assert int(np.asarray(z_acc).sum()) == 0
    assert int(np.argmax(np.asarray(z_rank)[0])) < ke


@given(st.integers(0, 2**31 - 1), st.integers(1, 3), st.integers(1, 4),
       st.integers(1, 3))
@settings(**SETTINGS)
def test_tree_row_mask_accept_equals_subproblem(seed, width, depth, branch):
    """The tree-arm contract (DESIGN.md §11): accepting the full lex-ordered
    path list under ``row_mask = path_max_branch < width_b`` is EXACTLY
    acceptance on the width_b sub-tree's own path list — the row_mask
    rendering of the k_eff prefix property, for the non-prefix eligibility
    pattern trees induce."""
    from repro.core.tree import topology
    rng = np.random.default_rng(seed)
    topo = topology(width, depth, branch)
    P = topo.num_paths
    wb = int(rng.integers(1, width + 1))
    sub = topo.path_max_branch < wb                       # (P,) eligibility
    drafts = jnp.asarray(rng.integers(0, 3, (1, P, depth)), jnp.int32)
    greedy = jnp.asarray(rng.integers(0, 3, (1, P, depth + 1)), jnp.int32)
    m = accept(drafts, greedy, row_mask=jnp.asarray(sub[None]))
    d = accept(drafts[:, sub, :], greedy[:, sub, :])
    # eligibility preserves lex order, so winners map through the subset
    assert int(m.winner[0]) == int(np.flatnonzero(sub)[int(d.winner[0])])
    assert int(m.n_commit[0]) == int(d.n_commit[0])
    n = int(m.n_commit[0])
    np.testing.assert_array_equal(np.asarray(m.tokens[0, :n]),
                                  np.asarray(d.tokens[0, :n]))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=6, deadline=None)
def test_adaptive_random_arms_respect_mask_and_budget(seed):
    """Random arm tables + random eos/budget mixes: no step may commit a
    slot more tokens than its chosen arm's w + 1, adaptation stays
    lossless vs greedy (incl. eos truncation), and calls < tokens."""
    from repro.core.ngram_tables import tables_from_counts
    from repro.core.spec_engine import (SpecConfig, greedy_reference,
                                        init_decode_state, spec_step)
    from repro.models import model as M
    from repro.models.config import ModelConfig
    rng = np.random.default_rng(seed)
    cfg = ModelConfig(name="t-adapt", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64,
                      vocab_size=int(rng.integers(17, 41)),
                      param_dtype=jnp.float32,
                      compute_dtype=jnp.float32).validate()
    params = M.init_params(jax.random.PRNGKey(seed % 1000), cfg)
    counts = jnp.asarray(rng.random((cfg.vocab_size, cfg.vocab_size)),
                         jnp.float32)
    tables = tables_from_counts(counts, k_max=4, w_max=4)
    k_max, w_max = 4, 4
    n_arms = int(rng.integers(1, 4))
    arms = tuple((int(rng.integers(1, k_max + 1)),
                  int(rng.integers(0, w_max + 1))) for _ in range(n_arms))
    ws = np.asarray([a[1] for a in arms])
    B, P, N = 2, 6, 10
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P)), jnp.int32)
    ref = np.asarray(greedy_reference(params, cfg, prompt, N))
    budget = np.asarray([int(rng.integers(3, N + 1)), N])
    eos = np.asarray([-1, int(ref[1, P + rng.integers(0, 5)])])
    spec = SpecConfig(k=k_max, w=w_max, strategy="mixed", max_new_tokens=N,
                      arms=arms)
    state = init_decode_state(params, cfg, spec, prompt,
                              max_new_tokens=jnp.asarray(budget),
                              eos_id=jnp.asarray(eos))
    for _ in range(64):
        if not bool(np.asarray(~state.done).any()):
            break
        prev_len = np.asarray(state.buf_len)
        state = spec_step(params, cfg, spec, state, tables)
        delta = np.asarray(state.buf_len) - prev_len
        arm_last = np.asarray(state.stats["arm_last"])
        # the per-step commit is bounded by the CHOSEN arm's depth + bonus
        assert (delta <= ws[arm_last] + 1).all(), (delta, arms, arm_last)
    else:
        raise AssertionError("did not converge")
    # lossless vs greedy under truncation, and speculation cost accounting
    for b in range(B):
        out = np.asarray(state.buf[b, P:int(state.buf_len[b])])
        expect = list(ref[b, P:P + budget[b]])
        if eos[b] >= 0 and eos[b] in expect:
            expect = expect[:expect.index(eos[b]) + 1]
        np.testing.assert_array_equal(out, np.asarray(expect, np.int32))
    calls = np.asarray(state.stats["calls"])
    tokens = np.asarray(state.stats["tokens"])
    assert (calls < tokens).all()     # the free prefill token guarantees <


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_spec_equals_greedy_random_models(seed):
    """The paper's core guarantee, for random tiny models and prompts."""
    from repro.core.ngram_tables import NGramTables, tables_from_counts
    from repro.core.spec_engine import SpecConfig, generate, greedy_reference
    from repro.models import model as M
    from repro.models.config import ModelConfig
    rng = np.random.default_rng(seed)
    cfg = ModelConfig(name="t", num_layers=int(rng.integers(1, 3)),
                      d_model=32, num_heads=2,
                      num_kv_heads=int(rng.choice([1, 2])), d_ff=64,
                      vocab_size=int(rng.integers(17, 41)),
                      param_dtype=jnp.float32,
                      compute_dtype=jnp.float32).validate()
    params = M.init_params(jax.random.PRNGKey(seed % 1000), cfg)
    # arbitrary (even mismatched) tables: correctness cannot depend on them
    counts = jnp.asarray(rng.random((cfg.vocab_size, cfg.vocab_size)),
                         jnp.float32)
    tables = tables_from_counts(counts, k_max=4, w_max=4)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 6)), jnp.int32)
    N = 10
    ref = greedy_reference(params, cfg, prompt, N)
    spec = SpecConfig(k=int(rng.integers(1, 4)), w=int(rng.integers(1, 4)),
                      strategy="mixed", max_new_tokens=N)
    buf, _, _ = generate(params, cfg, spec, prompt, tables)
    np.testing.assert_array_equal(np.asarray(buf[:, :6 + N]),
                                  np.asarray(ref))
