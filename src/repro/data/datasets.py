"""Synthetic corpora mirroring the statistical structure of the paper's
evaluation suites (no external data — consistent with the paper's P2):

  - ``code``  (HumanEval-like): templated Python with heavy token repetition
              -> long context-N-gram matches (the paper observes w=10
              acceptances most often here, Fig. 4);
  - ``math``  (GSM8K-like): templated word problems + digit arithmetic ->
              wide acceptance-length distribution;
  - ``chat``  (MTBench-like): multi-turn Q&A with many unique tokens ->
              hardest for context N-grams, bigram does the work.
"""
from __future__ import annotations

import random
import zlib
from typing import List, Tuple

_NAMES = ["Ada", "Bert", "Caro", "Dan", "Eve", "Finn", "Gus", "Hana",
          "Ivan", "Jo", "Kira", "Liam"]
_ITEMS = ["apples", "books", "coins", "pens", "shells", "stamps", "tokens",
          "cards"]
_VERBS = ["buys", "sells", "finds", "loses", "makes", "trades"]
_TOPICS = ["the ocean", "a small town", "ancient history", "modern art",
           "machine learning", "gardening", "astronomy", "cooking",
           "chess strategy", "mountain hiking"]
_ADJS = ["brief", "detailed", "simple", "vivid", "formal", "playful"]

_CODE_FUNCS = [
    ("add_numbers", "a + b"), ("sub_numbers", "a - b"),
    ("mul_numbers", "a * b"), ("max_of_two", "a if a > b else b"),
    ("min_of_two", "a if a < b else b"),
]


def _code_example(rng: random.Random) -> str:
    name, expr = rng.choice(_CODE_FUNCS)
    n = rng.randint(2, 4)
    lines = [f"def {name}(a, b):",
             f"    \"\"\"Return {expr} for the inputs a and b.\"\"\"",
             f"    result = {expr}",
             "    return result",
             ""]
    for i in range(n):
        x, y = rng.randint(0, 20), rng.randint(0, 20)
        lines.append(f"assert {name}({x}, {y}) == {name}({x}, {y})")
    lines.append(f"print({name}({rng.randint(0,9)}, {rng.randint(0,9)}))")
    return "\n".join(lines)


def _math_example(rng: random.Random) -> str:
    who = rng.choice(_NAMES)
    item = rng.choice(_ITEMS)
    a, b, c = rng.randint(2, 30), rng.randint(2, 30), rng.randint(2, 9)
    return (f"Question: {who} has {a} {item}. {who} {rng.choice(_VERBS)} "
            f"{b} more {item} and then gives away {c} {item}. How many "
            f"{item} does {who} have now?\n"
            f"Answer: {who} starts with {a} {item}. After getting {b} more, "
            f"{who} has {a} + {b} = {a+b} {item}. After giving away {c}, "
            f"{who} has {a+b} - {c} = {a+b-c} {item}. The answer is "
            f"{a+b-c}.")


def _chat_example(rng: random.Random) -> str:
    topic = rng.choice(_TOPICS)
    adj = rng.choice(_ADJS)
    t2 = rng.choice(_TOPICS)
    return (f"User: Give me a {adj} explanation of {topic}.\n"
            f"Assistant: Here is a {adj} explanation of {topic}. The most "
            f"important thing to understand about {topic} is how its parts "
            f"fit together, and why people who study {topic} care about it.\n"
            f"User: Now compare {topic} with {t2}.\n"
            f"Assistant: Comparing {topic} with {t2}: both reward patience, "
            f"but {t2} demands different skills than {topic}.")


_MAKERS = {"code": _code_example, "math": _math_example, "chat": _chat_example}
TASKS = tuple(_MAKERS)


def make_corpus(task: str, n_examples: int, seed: int = 0) -> List[str]:
    # crc32, not hash(): str hashing is randomized per process
    # (PYTHONHASHSEED), which would make "seeded" corpora differ across
    # runs — benchmarks and sharded training both need them reproducible.
    rng = random.Random(seed * 7919 + zlib.crc32(task.encode()) % 1000)
    return [_MAKERS[task](rng) for _ in range(n_examples)]


def make_prompts(task: str, n: int, seed: int = 0
                 ) -> List[Tuple[str, str]]:
    """(prompt, reference-continuation) pairs: prompt = first half of an
    example, mimicking the paper's 'continue the benchmark example' setup."""
    out = []
    for ex in make_corpus(task, n, seed + 1):
        cut = len(ex) // 2
        out.append((ex[:cut], ex[cut:]))
    return out
