"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Faithful to arXiv:2405.04517 at block level:
  - mLSTM block: pre-LN -> up-proj (pf=2) -> causal conv + silu -> q/k/v ->
    matrix-memory cell with exponential gating + stabiliser -> per-head
    group-norm -> gate with silu(z) -> down-proj.
  - sLSTM block: pre-LN -> headwise recurrent cell (h_{t-1} feedback, which
    makes it inherently sequential) -> group-norm -> gated FFN (pf=4/3).

Sequence processing uses ``lax.scan`` over time.  sLSTM *cannot* be
parallelised over time (gates see h_{t-1}); mLSTM can — the chunkwise-parallel
mLSTM form is implemented as a beyond-paper perf option (see
``mlstm_mix_chunkwise`` and EXPERIMENTS.md §Perf).

States (per layer):
  mLSTM: C (B,H,dh,dh) f32, n (B,H,dh) f32, m (B,H) f32
  sLSTM: c,n,h (B,H,dh) f32, m (B,H,dh) f32
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init

Params = Dict[str, jnp.ndarray]


# ----------------------------------------------------------------------------
# mLSTM
# ----------------------------------------------------------------------------
def init_mlstm(rng, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di = int(d * cfg.xlstm_mlstm_proj_factor)
    nh = cfg.num_heads
    dt = cfg.param_dtype
    ks = jax.random.split(rng, 8)
    return {
        "up_proj": dense_init(ks[0], (d, 2 * di), dt),
        "conv_w": dense_init(ks[1], (cfg.xlstm_conv_kernel, di), dt),
        "conv_b": jnp.zeros((di,), dt),
        "wq": dense_init(ks[2], (di, di), dt),
        "wk": dense_init(ks[3], (di, di), dt),
        "wv": dense_init(ks[4], (di, di), dt),
        "w_if": dense_init(ks[5], (di, 2 * nh), dt),
        "b_i": jnp.zeros((nh,), jnp.float32) - 3.0,
        "b_f": jnp.zeros((nh,), jnp.float32) + 3.0,
        "gn_scale": jnp.ones((di,), dt),
        "skip": jnp.ones((di,), dt),
        "down_proj": dense_init(ks[6], (di, d), dt),
    }


def _groupnorm_heads(x: jnp.ndarray, scale: jnp.ndarray, nh: int,
                     eps: float = 1e-5) -> jnp.ndarray:
    """Per-head group norm over (..., di) with di = nh*dh."""
    shp = x.shape
    xh = x.reshape(shp[:-1] + (nh, shp[-1] // nh)).astype(jnp.float32)
    mu = xh.mean(axis=-1, keepdims=True)
    var = xh.var(axis=-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(shp) * scale.astype(jnp.float32)).astype(x.dtype)


def _mlstm_cell_scan(q, k, v, log_i, log_f, C0, n0, m0, per_step: bool = False):
    """Recurrent mLSTM cell over time.

    q/k/v: (B,T,H,dh) f32; log_i/log_f: (B,T,H) f32.
    Returns h (B,T,H,dh), (C,n,m) finals — or per-step state trees with a
    (B, T, ...) leading layout when ``per_step`` (speculative commit path).
    """
    dh = q.shape[-1]
    k = k / (dh ** 0.5)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, li, lf = xs
        m_new = jnp.maximum(lf + m, li)                       # (B,H)
        i_p = jnp.exp(li - m_new)[..., None]
        f_p = jnp.exp(lf + m - m_new)[..., None]
        C = f_p[..., None] * C + i_p[..., None] * (vt[..., :, None]
                                                   * kt[..., None, :])
        n = f_p * n + i_p * kt
        num = jnp.einsum("bhvk,bhk->bhv", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)),
                          jnp.exp(-m_new))[..., None]
        out = num / den
        y = (out, (C, n, m_new)) if per_step else out
        return (C, n, m_new), y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, log_i, log_f))
    final, ys = jax.lax.scan(step, (C0, n0, m0), xs)
    if per_step:
        hs, states = ys
        states = jax.tree_util.tree_map(lambda a: jnp.moveaxis(a, 0, 1), states)
        return jnp.moveaxis(hs, 0, 1), states
    return jnp.moveaxis(ys, 0, 1), final


def _mlstm_one_chunk(q, k, v, log_i, log_f, C0, n0, m0):
    """Single-chunk quadratic mLSTM over the whole sequence (scan-free)."""
    dh = q.shape[-1]
    k = k / (dh ** 0.5)
    body = _make_mlstm_chunk_body(q.shape[1])
    (C, n, m), h = body((C0, n0, m0), (q, k, v, log_i, log_f))
    return h, (C, n, m)


def _mlstm_cell_chunkwise(q, k, v, log_i, log_f, C0, n0, m0, chunk: int = 128):
    """Chunkwise-parallel mLSTM (beyond-paper perf path; same math).

    Intra-chunk contributions use a masked quadratic (attention-like) form;
    inter-chunk state is carried with scan.  Numerically stabilised per chunk.
    """
    B, T, H, dh = q.shape
    if T % chunk != 0 or T <= chunk:
        return _mlstm_cell_scan(q, k, v, log_i, log_f, C0, n0, m0)
    k = k / (dh ** 0.5)
    nc = T // chunk

    def rs(a):  # (B,T,...) -> (nc, B, c, ...)
        return jnp.moveaxis(a.reshape(B, nc, chunk, *a.shape[2:]), 1, 0)

    qc, kc, vc, lic, lfc = map(rs, (q, k, v, log_i, log_f))
    body = _make_mlstm_chunk_body(chunk)
    (C, n, m), hs = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    return jnp.moveaxis(hs, 0, 1).reshape(B, T, H, dh), (C, n, m)


def _make_mlstm_chunk_body(chunk: int):
    def body(carry, xs):
        # C is stored with log-scale m: true state = C * exp(m).
        C, n, m = carry                       # (B,H,dh,dh),(B,H,dh),(B,H)
        qt, kt, vt, li, lf = xs               # (B,c,H,*)
        li = jnp.moveaxis(li, -1, 1)          # (B,H,c)
        lf = jnp.moveaxis(lf, -1, 1)
        F = jnp.cumsum(lf, axis=-1)           # logF_t (B,H,c)
        a = li - F                            # a_s = li_s - logF_s
        # stabiliser: m_t = logF_t + max(m_carry, cummax_s<=t a_s)
        m_t = F + jnp.maximum(m[..., None],
                              jax.lax.cummax(a, axis=a.ndim - 1))  # (B,H,c)
        # source weights w[t,s] = exp(logF_t + a_s - m_t), s <= t
        i_w = jnp.exp(F[..., :, None] + a[..., None, :] - m_t[..., :, None])
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        i_w = jnp.where(mask, i_w, 0.0)                        # (B,H,t,s)
        carry_w = jnp.exp(F + m[..., None] - m_t)              # (B,H,c)
        qh = jnp.moveaxis(qt, 1, 2)          # (B,H,c,dh)
        kh = jnp.moveaxis(kt, 1, 2)
        vh = jnp.moveaxis(vt, 1, 2)
        # intra-chunk attention-like term + inter-chunk carried state
        qk = jnp.einsum("bhtd,bhsd->bhts", qh, kh) * i_w
        num = jnp.einsum("bhts,bhsd->bhtd", qk, vh)
        num = num + carry_w[..., None] * jnp.einsum("bhvk,bhtk->bhtv", C, qh)
        nvec = jnp.einsum("bhts,bhsd->bhtd", i_w, kh)
        nvec = nvec + carry_w[..., None] * n[..., None, :]
        den = jnp.abs(jnp.einsum("bhtd,bhtd->bht", nvec, qh))
        den = jnp.maximum(den, jnp.exp(-m_t))[..., None]
        h = num / den                          # (B,H,c,dh)
        # chunk-final state, stored at scale m_new = m_t[last]
        m_new = m_t[..., -1]
        w_s = jnp.exp(F[..., -1:] + a - m_new[..., None])      # (B,H,c)
        decay = jnp.exp(F[..., -1] + m - m_new)
        C_new = (decay[..., None, None] * C
                 + jnp.einsum("bhs,bhsv,bhsk->bhvk", w_s, vh, kh))
        n_new = (decay[..., None] * n
                 + jnp.einsum("bhs,bhsk->bhk", w_s, kh))
        return (C_new, n_new, m_new), jnp.moveaxis(h, 2, 1)

    return body


def mlstm_mix(params: Params, x: jnp.ndarray, cfg: ModelConfig,
              state: Tuple, conv_state: jnp.ndarray,
              chunkwise: bool = False, per_step: bool = False):
    """x: (B,T,d). state: (C,n,m). conv_state: (B, k-1, di).

    Returns (y, new_state, new_conv_state).  With ``per_step``, new_state
    leaves are (B, T, ...) per-step states and new_conv_state is the full
    (B, T+k-1, di) conv window extension (commit selects a slice).
    """
    from .mamba import _causal_conv_full  # same depthwise causal conv
    cd = cfg.compute_dtype
    nh = cfg.num_heads
    B, T, _ = x.shape
    up = x.astype(cd) @ params["up_proj"].astype(cd)
    xm, z = jnp.split(up, 2, axis=-1)
    di = xm.shape[-1]
    dh = di // nh
    if per_step:
        # keep the full conv window extension so commit can select any step
        dc = params["conv_w"].shape[0]
        ext = jnp.concatenate([conv_state.astype(xm.dtype), xm], axis=1)
        xc = jnp.zeros_like(xm)
        for i in range(dc):
            xc = xc + ext[:, i:i + xm.shape[1], :] * \
                params["conv_w"][i].astype(xm.dtype)
        xc = xc + params["conv_b"].astype(xm.dtype)
        new_conv = ext
    else:
        xc, new_conv = _causal_conv_full(xm, params["conv_w"],
                                         params["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    q = (xc @ params["wq"].astype(cd)).reshape(B, T, nh, dh).astype(jnp.float32)
    k = (xc @ params["wk"].astype(cd)).reshape(B, T, nh, dh).astype(jnp.float32)
    v = (xm @ params["wv"].astype(cd)).reshape(B, T, nh, dh).astype(jnp.float32)
    if_gates = (xc @ params["w_if"].astype(cd)).astype(jnp.float32)
    log_i = if_gates[..., :nh] + params["b_i"]
    log_f = jax.nn.log_sigmoid(if_gates[..., nh:] + params["b_f"])
    # NOTE: in roofline-calibration (UNROLL) mode the mLSTM stays a scan on
    # purpose — the quadratic chunk form has *different* FLOPs than the
    # production recurrence; the missing (T-1) body repeats are corrected
    # analytically in benchmarks/roofline.py, like sLSTM.
    if per_step:
        h, new_state = _mlstm_cell_scan(q, k, v, log_i, log_f, *state,
                                        per_step=True)
    else:
        cell = _mlstm_cell_chunkwise if chunkwise else _mlstm_cell_scan
        h, new_state = cell(q, k, v, log_i, log_f, *state)
    h = h.reshape(B, T, di).astype(cd)
    h = _groupnorm_heads(h, params["gn_scale"], nh)
    h = h + params["skip"].astype(cd) * xc
    y = (h * jax.nn.silu(z)) @ params["down_proj"].astype(cd)
    return y, new_state, new_conv


def init_mlstm_state(cfg: ModelConfig, batch: int):
    nh = cfg.num_heads
    di = int(cfg.d_model * cfg.xlstm_mlstm_proj_factor)
    dh = di // nh
    C = jnp.zeros((batch, nh, dh, dh), jnp.float32)
    n = jnp.zeros((batch, nh, dh), jnp.float32)
    m = jnp.zeros((batch, nh), jnp.float32) - 1e9
    conv = jnp.zeros((batch, cfg.xlstm_conv_kernel - 1, di), cfg.compute_dtype)
    return (C, n, m), conv


# ----------------------------------------------------------------------------
# sLSTM
# ----------------------------------------------------------------------------
def init_slstm(rng, cfg: ModelConfig) -> Params:
    d, nh = cfg.d_model, cfg.num_heads
    dh = d // nh
    dt = cfg.param_dtype
    d_ff = int(d * cfg.xlstm_slstm_proj_factor)
    ks = jax.random.split(rng, 7)
    # input weights for z,i,f,o ; headwise recurrent weights
    return {
        "w_in": dense_init(ks[0], (d, 4 * d), dt),
        "r": dense_init(ks[1], (4, nh, dh, dh), jnp.float32, scale=1.0),
        "b": jnp.concatenate([jnp.zeros((d,)), jnp.zeros((d,)) - 3.0,
                              jnp.zeros((d,)) + 3.0,
                              jnp.zeros((d,))]).astype(jnp.float32),
        "gn_scale": jnp.ones((d,), dt),
        "ffn_gate": dense_init(ks[2], (d, d_ff), dt),
        "ffn_up": dense_init(ks[3], (d, d_ff), dt),
        "ffn_down": dense_init(ks[4], (d_ff, d), dt),
    }


def slstm_mix(params: Params, x: jnp.ndarray, cfg: ModelConfig, state: Tuple,
              per_step: bool = False):
    """x: (B,T,d); state: (c,n,h,m) each (B,H,dh) f32. Sequential by nature.

    With ``per_step`` the returned state leaves are (B, T, ...)."""
    cd = cfg.compute_dtype
    nh = cfg.num_heads
    B, T, d = x.shape
    dh = d // nh
    pre = (x.astype(cd) @ params["w_in"].astype(cd)).astype(jnp.float32)
    pre = pre + params["b"]
    pre = pre.reshape(B, T, 4, nh, dh)
    R = params["r"]  # (4, nh, dh, dh)

    def step(carry, xt):
        c, n, h, m = carry
        rec = jnp.einsum("ghij,bhj->bghi", R, h)  # (B,4,H,dh)
        zt = jnp.tanh(xt[:, 0] + rec[:, 0])
        it = xt[:, 1] + rec[:, 1]
        ft = jax.nn.log_sigmoid(xt[:, 2] + rec[:, 2])
        ot = jax.nn.sigmoid(xt[:, 3] + rec[:, 3])
        m_new = jnp.maximum(ft + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(ft + m - m_new)
        c_new = f_p * c + i_p * zt
        n_new = f_p * n + i_p
        h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
        carry = (c_new, n_new, h_new, m_new)
        return carry, ((h_new, carry) if per_step else h_new)

    xs = jnp.moveaxis(pre, 1, 0)
    new_state, ys = jax.lax.scan(step, state, xs)
    if per_step:
        hs, states = ys
        new_state = jax.tree_util.tree_map(lambda a: jnp.moveaxis(a, 0, 1),
                                           states)
    else:
        hs = ys
    h = jnp.moveaxis(hs, 0, 1).reshape(B, T, d).astype(cd)
    h = _groupnorm_heads(h, params["gn_scale"], nh)
    # gated FFN (pf = 4/3)
    g = jax.nn.gelu(h @ params["ffn_gate"].astype(cd))
    u = h @ params["ffn_up"].astype(cd)
    y = (g * u) @ params["ffn_down"].astype(cd)
    return y, new_state


def init_slstm_state(cfg: ModelConfig, batch: int):
    nh = cfg.num_heads
    dh = cfg.d_model // nh
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return (z, z, z, z - 1e9)
