"""Nemotron-4-340B: GQA kv=8, squared-ReLU MLP, 50% partial rotary,
LayerNorm [arXiv:2402.16819]."""
import jax.numpy as jnp
from ..models.config import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b", arch_type="dense", source="arXiv:2402.16819",
        num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8,
        d_ff=73728, vocab_size=256000,
        block_pattern=(BlockSpec("attn", "relu2"),),
        norm="layernorm", rope="rope", partial_rotary_factor=0.5,
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-smoke", arch_type="dense", source="arXiv:2402.16819",
        num_layers=2, d_model=192, num_heads=4, num_kv_heads=2,
        d_ff=384, vocab_size=512,
        block_pattern=(BlockSpec("attn", "relu2"),),
        norm="layernorm", rope="rope", partial_rotary_factor=0.5,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    ).validate()
