"""Figure 2 reproduction: tokens/call as a function of k for the
model-derived unigram, bigram, and extended bigram (w in {1, 2, 3}).

Run on the tiny trained benchmark model over the code + chat tasks (the
paper uses MT-Bench + HumanEval on Mistral-7B-Instruct).
"""
from __future__ import annotations

import csv
import os

from repro.core.spec_engine import SpecConfig

from .common import ensure_dirs, get_tables, get_trained, measure

KS = (1, 5, 10, 25)


def run(out_dir: str = "experiments/results", max_new: int = 48) -> dict:
    ensure_dirs()
    cfg, params = get_trained()
    tables = get_tables(cfg, params)
    path = os.path.join(out_dir, "fig2_topk_curves.csv")
    best = {}
    with open(path, "w", newline="") as f:
        wr = csv.writer(f)
        wr.writerow(["task", "strategy", "k", "w", "tokens_per_call"])
        for task in ("code", "chat"):
            for strat, w in (("unigram", 1), ("bigram", 1), ("bigram", 2),
                             ("bigram", 3)):
                for k in KS:
                    spec = SpecConfig(k=k, w=w, strategy=strat,
                                      max_new_tokens=max_new)
                    r = measure(cfg, params, tables, task, spec,
                                n_prompts=4)
                    wr.writerow([task, f"{strat}-w{w}", k,
                                 w, f"{r.tokens_per_call:.3f}"])
                    best[(task, strat, w, k)] = r.tokens_per_call
    return {"csv": path, "results": best}


def main():
    res = run()
    print("fig2_topk_curves ->", res["csv"])
    for (task, strat, w, k), v in sorted(res["results"].items()):
        if k == 25:
            print(f"  {task:5s} {strat:8s} w={w} k={k}: {v:.2f} tok/call")


if __name__ == "__main__":
    main()
