"""Model-call microbenchmark (the engine-level analogue of the paper's
CUDA-event timings): CPU wall time per call for decode (1,1) vs verification
(k, w+1), plus the drafter cost — demonstrating 'negligible-cost' drafting
(P1/P2): the drafter must be orders of magnitude cheaper than a model call.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.drafters import mixed_draft
from repro.models import model as M

from .common import ensure_dirs, get_tables, get_trained


def _time(fn, *args, n=20):
    out = fn(*args)
    jax.tree_util.tree_leaves(out)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.tree_util.tree_leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6  # us


def run(max_len: int = 256) -> dict:
    ensure_dirs()
    cfg, params = get_trained()
    tables = get_tables(cfg, params)
    B, P = 4, 64
    toks = jax.random.randint(jax.random.PRNGKey(0), (B, P), 0,
                              cfg.vocab_size)
    state = M.init_state(cfg, B, max_len)
    _, state = jax.jit(lambda s, t: M.prefill(params, cfg, s, tokens=t)
                       )(state, toks)
    rows = []

    dec = jax.jit(lambda s, t: M.decode(params, cfg, s, t))
    us_dec = _time(lambda: dec(state, toks[:, :1]))
    rows.append(("call_decode_1x1", us_dec, "baseline"))

    for (k, w) in [(5, 4), (10, 10), (25, 14)]:
        vt = jax.random.randint(jax.random.PRNGKey(1), (B, k, w + 1), 0,
                                cfg.vocab_size)
        ver = jax.jit(lambda s, r: M.verify(params, cfg, s, r))
        us_v = _time(lambda: ver(state, vt))
        rows.append((f"call_verify_k{k}_w{w}", us_v,
                     f"slowdown_vs_decode={us_v/us_dec:.2f}x"))

    buf = jnp.zeros((B, max_len), jnp.int32
                    ).at[:, :P].set(toks)
    cur = jnp.full((B,), P, jnp.int32)
    drafter = jax.jit(lambda b, c, l: mixed_draft(tables, b, c, l, 1, 10, 10))
    us_d = _time(lambda: drafter(buf, cur, toks[:, -1]))
    rows.append(("drafter_mixed_k10_w10", us_d,
                 f"fraction_of_decode_call={us_d/us_dec:.3f}"))
    return {"rows": rows}


def main():
    for name, us, derived in run()["rows"]:
        print(f"{name:24s} {us:10.0f} us   {derived}")


if __name__ == "__main__":
    main()
