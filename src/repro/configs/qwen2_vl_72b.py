"""Qwen2-VL-72B language backbone: M-RoPE, GQA kv=8 [arXiv:2409.12191].
Vision frontend is a STUB per the assignment — input_specs() feeds
precomputed patch embeddings; mrope_section = (16, 24, 24)."""
import jax.numpy as jnp
from ..models.config import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b", arch_type="vlm", source="arXiv:2409.12191",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=29568, vocab_size=152064,
        block_pattern=(BlockSpec("attn", "swiglu"),),
        norm="rmsnorm", rope="mrope", rope_theta=1e6,
        mrope_sections=(16, 24, 24),
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke", arch_type="vlm", source="arXiv:2409.12191",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=512,
        block_pattern=(BlockSpec("attn", "swiglu"),),
        norm="rmsnorm", rope="mrope", rope_theta=1e6,
        mrope_sections=(6, 5, 5),
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    ).validate()
