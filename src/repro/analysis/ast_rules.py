"""Level-2 (AST) lint rules over ``src/repro`` — repo-specific invariants.

Each rule mechanizes a contract DESIGN.md states in prose, with the PR
whose bug class motivated it:

  - ``pallas-scope``   — ``pallas_call`` only inside ``kernels/``: the
    dispatch layer (DESIGN.md §7) is the single seam where backend choice
    lives; a stray kernel call elsewhere bypasses the xla/pallas parity
    contract and the mesh seam (a pallas_call is opaque to the SPMD
    partitioner).
  - ``tracer-branch``  — no Python ``if``/``while`` on jnp-derived values
    in ``core/``: the engine bodies are jitted, so a host branch on a
    tracer either crashes late (ConcretizationTypeError) or silently
    splits the one-trace contract via recompiles.
  - ``hash-constants`` — the continuation-hash constants live ONLY in
    ``kernels/hashing.py``; a re-derived constant elsewhere silently
    breaks drafter/kernel/oracle bit-agreement (the pre-PR-2 state).
  - ``global-state``   — no module-level env-var / global-mesh mutation,
    and every ``act_sharding.install`` call needs an ``uninstall`` /
    ``activated`` pairing in the same module (PR 5: dryrun clobbered
    XLA_FLAGS at import; an installed mesh leaked across engines and
    pinned attn_verify off the Pallas path).
  - ``time-in-jit``    — no wall-clock / host-RNG calls inside jitted
    bodies (decorated with ``jax.jit`` or following the ``*_body`` naming
    idiom): they execute once at trace time and bake a constant into the
    executable.
  - ``host-sync`` (AST half) — every device->host readback in the
    continuous-serving critical path must carry an inline waiver stating
    why it cannot be deferred; the resulting inventory is the starting
    map for the ROADMAP's async-serving item (jaxpr half:
    jaxpr_rules.check_host_sync).
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .findings import Finding, apply_waivers, scan_waivers

# repro-lint: allow(hash-constants): the linter must name the constants it hunts
HASH_CONSTANTS = {2654435761, 0x9E3779B9}
HASH_NAMES = {"HASH_MULT", "HASH_MIX"}
# jax namespaces whose call results are (potential) tracers
_TRACED_ROOTS = {"jnp"}
_TRACED_JAX_ATTRS = {"lax", "nn", "random", "numpy"}
_CLOCK_CALLS = {("time", "time"), ("time", "perf_counter"),
                ("time", "monotonic"), ("time", "process_time"),
                ("datetime", "now")}
# the continuous-serving decode critical path (serving/engine.py):
# everything called between two spec_step dispatches
CRITICAL_PATH_METHODS = {"step", "serve_continuous", "_retire_finished",
                         "_admit_queued", "_run_step", "_run_admit",
                         "_run_release"}


def _src_line(lines: Sequence[str], lineno: int) -> str:
    return lines[lineno - 1].strip() if 0 < lineno <= len(lines) else ""


def _mk(rule: str, relpath: str, node: ast.AST, lines: Sequence[str],
        message: str, hint: str) -> Finding:
    line = getattr(node, "lineno", 0)
    return Finding(rule=rule, file=relpath, line=line, message=message,
                   hint=hint, context=_src_line(lines, line))


def _attr_chain(node: ast.AST) -> str:
    """Dotted name of an attribute/name expression ('' if not one)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


# ---------------------------------------------------------------------------
# pallas-scope
# ---------------------------------------------------------------------------
def pallas_scope_findings(relpath: str, source: str,
                          tree: ast.Module) -> List[Finding]:
    if relpath.startswith("kernels/") or relpath.startswith("src/repro/kernels/"):
        return []
    lines = source.splitlines()
    out = []
    for node in ast.walk(tree):
        name = ""
        if isinstance(node, ast.Attribute) and node.attr == "pallas_call":
            name = _attr_chain(node)
        elif isinstance(node, ast.Name) and node.id == "pallas_call":
            name = node.id
        if name:
            out.append(_mk(
                "pallas-scope", relpath, node, lines,
                f"{name!r} outside kernels/ — kernel invocation bypasses "
                f"the dispatch layer (backend parity + mesh seam)",
                "route the call through kernels/dispatch.py (or move the "
                "kernel into kernels/)"))
    return out


# ---------------------------------------------------------------------------
# tracer-branch
# ---------------------------------------------------------------------------
def _is_traced_expr(node: ast.AST, traced: Set[str]) -> bool:
    if isinstance(node, ast.Name):
        return node.id in traced
    if isinstance(node, ast.Call):
        chain = _attr_chain(node.func)
        root = chain.split(".")[0] if chain else ""
        if root in _TRACED_ROOTS:
            return True
        if root == "jax" and len(chain.split(".")) > 1 \
                and chain.split(".")[1] in _TRACED_JAX_ATTRS:
            return True
        return False
    if isinstance(node, (ast.BinOp, ast.BoolOp, ast.Compare, ast.UnaryOp,
                         ast.IfExp, ast.Subscript)):
        return any(_is_traced_expr(c, traced) for c in ast.iter_child_nodes(node)
                   if isinstance(c, ast.expr))
    return False


def tracer_branch_findings(relpath: str, source: str,
                           tree: ast.Module) -> List[Finding]:
    if not (relpath.startswith("core/")
            or relpath.startswith("src/repro/core/")):
        return []
    lines = source.splitlines()
    out: List[Finding] = []

    def scan_fn(fn: ast.AST) -> None:
        traced: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) \
                    and _is_traced_expr(node.value, traced):
                for tgt in node.targets:
                    for n in ast.walk(tgt):
                        if isinstance(n, ast.Name):
                            traced.add(n.id)
            elif isinstance(node, ast.AugAssign) \
                    and _is_traced_expr(node.value, traced) \
                    and isinstance(node.target, ast.Name):
                traced.add(node.target.id)
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)) \
                    and _is_traced_expr(node.test, traced):
                kind = "if" if isinstance(node, ast.If) else "while"
                out.append(_mk(
                    "tracer-branch", relpath, node, lines,
                    f"Python `{kind}` on a jnp-derived value inside core/ "
                    f"— a host branch on a tracer crashes at trace time or "
                    f"splits the one-trace contract",
                    "use jnp.where / lax.cond / lax.select (runtime data "
                    "must steer VALUES, not Python control flow)"))

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scan_fn(node)
    return out


# ---------------------------------------------------------------------------
# hash-constants
# ---------------------------------------------------------------------------
def hash_constant_findings(relpath: str, source: str,
                           tree: ast.Module) -> List[Finding]:
    if relpath.endswith("kernels/hashing.py"):
        return []
    lines = source.splitlines()
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Constant) and isinstance(node.value, int) \
                and not isinstance(node.value, bool) \
                and node.value in HASH_CONSTANTS:
            out.append(_mk(
                "hash-constants", relpath, node, lines,
                f"continuation-hash constant {node.value} re-derived "
                f"outside kernels/hashing.py — drafter/kernel/oracle "
                f"bit-agreement now rests on a copy staying in sync",
                "import HASH_MULT/HASH_MIX/hash_step from "
                "repro.kernels.hashing instead"))
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id in HASH_NAMES:
                    out.append(_mk(
                        "hash-constants", relpath, node, lines,
                        f"redefinition of {tgt.id} outside "
                        f"kernels/hashing.py",
                        "import it from repro.kernels.hashing"))
    return out


# ---------------------------------------------------------------------------
# global-state
# ---------------------------------------------------------------------------
def _is_main_guard(node: ast.AST) -> bool:
    return (isinstance(node, ast.If)
            and isinstance(node.test, ast.Compare)
            and isinstance(node.test.left, ast.Name)
            and node.test.left.id == "__name__")


def _walk_no_defs(node: ast.AST):
    """Walk a statement WITHOUT descending into function/class bodies —
    code inside a def runs when called, not at import, so it is not
    module-level for the global-state rule."""
    yield node
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                         ast.ClassDef, ast.Lambda)):
        return
    for child in ast.iter_child_nodes(node):
        yield from _walk_no_defs(child)


def _module_level_stmts(tree: ast.Module):
    """Top-level statements, excluding `if __name__ == \"__main__\"` blocks
    (entry-point-only mutation is the documented pattern — dryrun/serve
    self-provision placeholder devices there, before jax locks the count).
    """
    for node in tree.body:
        if _is_main_guard(node):
            continue
        yield node


def _environ_mutation(node: ast.AST) -> Optional[str]:
    if isinstance(node, (ast.Assign, ast.AugAssign)):
        tgts = node.targets if isinstance(node, ast.Assign) else [node.target]
        for tgt in tgts:
            if isinstance(tgt, ast.Subscript) \
                    and _attr_chain(tgt.value).endswith("environ"):
                return "os.environ[...] assignment"
    if isinstance(node, ast.Delete):
        for tgt in node.targets:
            if isinstance(tgt, ast.Subscript) \
                    and _attr_chain(tgt.value).endswith("environ"):
                return "del os.environ[...]"
    if isinstance(node, ast.Call):
        chain = _attr_chain(node.func)
        if chain in ("os.putenv", "os.unsetenv"):
            return chain
        if chain.startswith("os.environ.") and chain.split(".")[-1] in (
                "setdefault", "update", "pop", "clear", "__setitem__"):
            return chain
    return None


def global_state_findings(relpath: str, source: str,
                          tree: ast.Module) -> List[Finding]:
    lines = source.splitlines()
    out: List[Finding] = []
    # (1) module-level mutation (import-time side effects: the PR-5 class)
    for stmt in _module_level_stmts(tree):
        for node in _walk_no_defs(stmt):
            kind = _environ_mutation(node)
            if kind:
                out.append(_mk(
                    "global-state", relpath, node, lines,
                    f"module-level environment mutation ({kind}) — runs at "
                    f"IMPORT time and clobbers caller state (the PR-5 "
                    f"XLA_FLAGS bug)",
                    "move it behind the `if __name__ == '__main__'` "
                    "entry-point guard or into an explicit function the "
                    "caller invokes"))
            if isinstance(node, ast.Call) \
                    and _attr_chain(node.func).endswith(
                        "act_sharding.install"):
                out.append(_mk(
                    "global-state", relpath, node, lines,
                    "module-level global-mesh install — leaks the mesh "
                    "into every engine in the process",
                    "use act_sharding.activated(mesh) scoped to the traces "
                    "that need it"))
    # (2) anywhere: install without an uninstall/activated pairing
    has_pairing = ("uninstall" in source) or ("activated(" in source)
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            if (chain.endswith("act_sharding.install")
                    or chain == "install" and "act_sharding" in source
                    and "from .act_sharding import" in source) \
                    and not has_pairing:
                out.append(_mk(
                    "global-state", relpath, node, lines,
                    "act_sharding.install(...) with no uninstall/activated "
                    "pairing in this module — an installed mesh outlives "
                    "its owner and pins attn_verify off the Pallas path",
                    "wrap the traces in act_sharding.activated(mesh), or "
                    "pair install with uninstall in a finally block"))
    return out


# ---------------------------------------------------------------------------
# time-in-jit
# ---------------------------------------------------------------------------
def _is_jit_decorated(fn: ast.AST) -> bool:
    for dec in getattr(fn, "decorator_list", []):
        target = dec.func if isinstance(dec, ast.Call) else dec
        chain = _attr_chain(target)
        if chain.endswith("jax.jit") or chain == "jit":
            return True
        if chain.endswith("functools.partial") or chain == "partial":
            if isinstance(dec, ast.Call) and dec.args \
                    and _attr_chain(dec.args[0]).endswith("jit"):
                return True
    return False


def time_in_jit_findings(relpath: str, source: str,
                         tree: ast.Module) -> List[Finding]:
    lines = source.splitlines()
    out: List[Finding] = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        # the repo's jitted-body idiom: module jits + `_*_body` functions
        # that jits and lax.while_loop wrap (spec_engine, serving)
        if not (_is_jit_decorated(fn) or fn.name.endswith("_body")):
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            parts = tuple(chain.split("."))
            is_clock = parts[-2:] in {c for c in _CLOCK_CALLS} \
                or chain in ("time.time", "time.perf_counter")
            is_host_rng = (parts[:1] == ("random",)
                           or parts[:2] == ("np", "random")
                           or parts[:2] == ("numpy", "random"))
            if is_clock or is_host_rng:
                out.append(_mk(
                    "time-in-jit", relpath, node, lines,
                    f"host call {chain!r} inside jitted body {fn.name!r} — "
                    f"executes once at TRACE time and bakes a constant "
                    f"into the executable",
                    "take the value as an argument (clocks) or use "
                    "jax.random with a threaded key (RNG)"))
    return out


# ---------------------------------------------------------------------------
# host-sync (AST half: the serving-loop critical path)
# ---------------------------------------------------------------------------
_SYNC_CALLS = {"np.asarray": "device->host transfer",
               "np.array": "device->host transfer",
               "jax.device_get": "device->host transfer",
               "numpy.asarray": "device->host transfer"}
_SYNC_METHODS = {"block_until_ready": "forced device sync",
                 "item": "scalar device->host sync",
                 "tolist": "device->host transfer"}


def serving_sync_findings(relpath: str, source: str, tree: ast.Module
                          ) -> Tuple[List[Finding], List[Dict]]:
    """Findings + full sync inventory for the continuous-serving critical
    path.  EVERY sync found is an inventory entry (waived included — the
    async-serving work needs the complete map); only un-waived ones are
    findings."""
    if not relpath.endswith("serving/engine.py"):
        return [], []
    lines = source.splitlines()
    out: List[Finding] = []
    inventory: List[Dict] = []

    def scan(method: ast.AST) -> None:
        for node in ast.walk(method):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            kind = _SYNC_CALLS.get(chain)
            if kind is None and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _SYNC_METHODS:
                kind = _SYNC_METHODS[node.func.attr]
                chain = node.func.attr
            if kind is None:
                continue
            f = _mk(
                "host-sync", relpath, node, lines,
                f"{kind} ({chain}) in continuous-serving critical path "
                f"method {method.name!r} — serializes the decode loop "
                f"(ROADMAP: async serving)",
                "defer the readback off the critical path, batch it with "
                "an existing sync, or waive with "
                "`# repro-lint: allow(host-sync): <why it cannot move>`")
            out.append(f)
            inventory.append({"file": relpath, "line": f.line,
                              "method": method.name, "call": chain,
                              "kind": kind, "code": f.context})

    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        for method in cls.body:
            if isinstance(method, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and method.name in CRITICAL_PATH_METHODS:
                scan(method)
    return out, inventory


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
AST_RULES = (pallas_scope_findings, tracer_branch_findings,
             hash_constant_findings, global_state_findings,
             time_in_jit_findings)


def analyze_source(relpath: str, source: str
                   ) -> Tuple[List[Finding], List[Dict]]:
    """All AST findings (waivers applied) + sync inventory for one file."""
    tree = ast.parse(source, filename=relpath)
    waivers = scan_waivers(source)
    findings: List[Finding] = []
    for rule in AST_RULES:
        findings += rule(relpath, source, tree)
    sync, inventory = serving_sync_findings(relpath, source, tree)
    findings += sync
    findings = apply_waivers(findings, waivers)
    for entry, f in zip(inventory,
                        [f for f in findings if f.rule == "host-sync"]):
        entry["waived"] = f.waived
        entry["reason"] = f.waive_reason
    return findings, inventory


def run_level2(root: str) -> Tuple[List[Finding], List[Dict]]:
    """Walk ``root`` (the ``src/repro`` package dir) and apply every AST
    rule.  Returns (findings, host-sync inventory)."""
    findings: List[Finding] = []
    inventory: List[Dict] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            relpath = os.path.relpath(path, root).replace(os.sep, "/")
            with open(path, encoding="utf-8") as f:
                source = f.read()
            got, inv = analyze_source(relpath, source)
            findings += got
            inventory += inv
    return findings, inventory
