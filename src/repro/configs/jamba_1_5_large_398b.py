"""Jamba-1.5-Large (398B): Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887].  Period-8 pattern: attention at offset 4, MoE on odd
layers; no explicit positional encoding (Jamba uses none)."""
import jax.numpy as jnp
from ..models.config import BlockSpec, ModelConfig

_PATTERN = tuple(
    BlockSpec("attn" if p == 4 else "mamba",
              "moe" if p % 2 == 1 else "swiglu")
    for p in range(8))


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", arch_type="hybrid",
        source="arXiv:2403.19887",
        num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=24576, vocab_size=65536,
        block_pattern=_PATTERN,
        num_experts=16, num_experts_per_tok=2,
        mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
        norm="rmsnorm", rope="none",
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", arch_type="hybrid", source="arXiv:2403.19887",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=512,
        block_pattern=(BlockSpec("mamba", "moe"), BlockSpec("attn", "swiglu")),
        num_experts=4, num_experts_per_tok=2,
        mamba_d_state=8, mamba_d_conv=4, mamba_expand=2,
        norm="rmsnorm", rope="none",
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    ).validate()
