"""GLM-4-9B: GQA kv=2, RoPE (half), SwiGLU [hf:THUDM/glm-4-9b]."""
import jax.numpy as jnp
from ..models.config import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", arch_type="dense", source="hf:THUDM/glm-4-9b",
        num_layers=40, d_model=4096, num_heads=32, num_kv_heads=2,
        d_ff=13696, vocab_size=151552,
        block_pattern=(BlockSpec("attn", "swiglu"),),
        norm="rmsnorm", rope="rope", partial_rotary_factor=0.5,
    ).validate()


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="glm4-smoke", arch_type="dense", source="hf:THUDM/glm-4-9b",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=512,
        block_pattern=(BlockSpec("attn", "swiglu"),),
        norm="rmsnorm", rope="rope", partial_rotary_factor=0.5,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    ).validate()
